//! Realtime (wall-clock) mode: the Resource Provision Service and one CMS
//! per department running as live services on the department-addressed
//! message bus — the shape of the paper's testbed run (§III-C), minus the
//! Xen boxes, generalized to any `[[department]]` roster under any
//! configured [`crate::provision::ProvisionPolicy`] (the virtual-time layer has been
//! N-department since the policy engine landed; this brings the serve
//! path level with it).
//!
//! This is the path `phoenixd serve` exercises; the figure experiments
//! use the virtual-time [`super::ConsolidationSim`] instead. Both paths
//! share the same servers ([`StServer`]/[`WsServer`]), ledger, and
//! policies; where the sim dispatches events, the serve loop pumps
//! [`Msg`] ticks — one quiescent bus dispatch per department per tick, in
//! department-id order, mirroring the sim's same-timestamp event
//! atomicity. The 2-department cooperative case reproduces the
//! virtual-time totals on tick-aligned traces (pinned in
//! `rust/tests/runtime_e2e.rs`).
//!
//! Runtime affiliation (arXiv:1003.0958): departments may join mid-run
//! ([`Msg::DeptJoin`], driven by `join_at` on the roster spec) and leave
//! again ([`Msg::DeptLeave`], driven by [`ServeDept::leave_at`]); a
//! leaver's holdings are force-reclaimed over the bus and returned to the
//! free pool.

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::{DeptId, DeptKind};
use crate::config::{DeptSpec, ExperimentConfig, RosterMix};
use crate::provision::{DeptProfile, PolicyChoice, PolicySpec, Rps};
use crate::services::monitor::Monitor;
use crate::services::{Bus, Ctx, Msg, Sender, Service, ServiceId, SubmitAck};
use crate::stcms::StServer;
use crate::trace::web_synth::RateSeries;
use crate::workload::{Job, JobState};
use crate::wscms::autoscaler::utilization;
use crate::wscms::{WsAction, WsServer};

use super::DeptSummary;

/// The scaling brain injected into a service CMS: maps (avg_util, rate)
/// to an instance target. Wraps the reactive rule, the PJRT forecaster,
/// or a replay of a precomputed demand series.
pub type ScalerFn = Box<dyn FnMut(f64, f64) -> u64>;

/// One department's input to the serve loop.
pub struct ServeDept {
    /// Identity, kind, quota, and (for runtime arrivals) `join_at`.
    pub spec: DeptSpec,
    pub workload: ServeWorkload,
    /// Trace second at which the department leaves again (its holdings
    /// are force-reclaimed to the free pool). `None` = stays to the end.
    pub leave_at: Option<u64>,
}

impl ServeDept {
    /// A batch department present from boot.
    pub fn batch(name: &str, quota: u64, jobs: impl Into<Arc<[Job]>>) -> Self {
        Self {
            spec: DeptSpec {
                name: name.to_string(),
                kind: DeptKind::Batch,
                tier: 1,
                quota,
                seed: None,
                join_at: 0,
                leave_at: 0,
            },
            workload: ServeWorkload::Batch(jobs.into()),
            leave_at: None,
        }
    }

    /// A service department present from boot (booted with one instance;
    /// the scaler takes over from the first tick).
    pub fn service(name: &str, quota: u64, rates: RateSeries, scaler: ScalerFn) -> Self {
        Self {
            spec: DeptSpec {
                name: name.to_string(),
                kind: DeptKind::Service,
                tier: 0,
                quota,
                seed: None,
                join_at: 0,
                leave_at: 0,
            },
            workload: ServeWorkload::Service { rates, scaler, boot_instances: 1 },
            leave_at: None,
        }
    }

    /// Turn this department into a runtime arrival at trace second `t`.
    pub fn joining_at(mut self, t: u64) -> Self {
        self.spec.join_at = t;
        self
    }

    /// Make the department leave at trace second `t`.
    pub fn leaving_at(mut self, t: u64) -> Self {
        self.leave_at = Some(t);
        self
    }
}

/// What a serve-path department runs.
pub enum ServeWorkload {
    /// Batch jobs, admitted to the department's ST-like CMS at their
    /// trace submit times (ticks quantize admission).
    Batch(Arc<[Job]>),
    /// A request-rate series driving a live autoscaler. `boot_instances`
    /// is granted from the free pool at t = 0 (the virtual-time sim's
    /// first-sample boot grant — pass the demand series' first sample to
    /// mirror it exactly); runtime joiners ignore it and claim on their
    /// first tick instead.
    Service { rates: RateSeries, scaler: ScalerFn, boot_instances: u64 },
}

// ---- shared run statistics ---------------------------------------------------
// The bus owns the boxed services; the driver reads these after the loop.

#[derive(Debug, Default)]
struct DeptStats {
    completed: Cell<u64>,
    killed: Cell<u64>,
    in_flight: Cell<usize>,
    turnaround_sum: Cell<f64>,
    holding: Cell<u64>,
    shortage: Cell<u64>,
    peak_demand: Cell<u64>,
}

#[derive(Debug, Default)]
struct RpsStats {
    force_returns: Cell<u64>,
    forced_nodes: Cell<u64>,
    denied: Cell<u64>,
    free: Cell<u64>,
    joins: Cell<u64>,
    leaves: Cell<u64>,
    crashes: Cell<u64>,
    recovers: Cell<u64>,
    down: Cell<u64>,
    forecast_mae: Cell<Option<f64>>,
    pregrant_hit_rate: Cell<Option<f64>>,
}

// ---- the RPS service ---------------------------------------------------------

/// The Resource Provision Service on the bus: owns the [`Rps`] (ledger +
/// policy) and routes every department-addressed resource flow.
struct RpsSvc {
    rps: Rps,
    /// Affiliated departments and their kinds (idle grants flow to the
    /// batch members; join/leave edit this roster at runtime).
    roster: BTreeMap<DeptId, DeptKind>,
    /// Outstanding forced returns: (victim, claimant), FIFO per victim.
    pending_force: VecDeque<(DeptId, DeptId)>,
    /// Departments whose leave is waiting for their [`Msg::Released`].
    leaving: Vec<DeptId>,
    stats: Rc<RpsStats>,
}

impl RpsSvc {
    fn batch_depts(&self) -> Vec<DeptId> {
        self.roster
            .iter()
            .filter(|&(_, &k)| k == DeptKind::Batch)
            .map(|(&d, _)| d)
            .collect()
    }

    /// "If there are idle resources, provision all of them" (§II-B),
    /// generalized: the policy distributes the free pool over the batch
    /// members of the roster.
    fn provision_idle_to_batch(&mut self, ctx: &mut Ctx<'_>) {
        if self.rps.ledger().free() == 0 {
            return;
        }
        let batch = self.batch_depts();
        for (d, n) in self.rps.provision_idle(&batch, ctx.now()) {
            if n > 0 {
                ctx.send_to_dept(d, Msg::Grant { dept: d, nodes: n });
            }
        }
    }

    fn sync(&self) {
        self.stats.free.set(self.rps.ledger().free());
        self.stats.force_returns.set(self.rps.force_returns);
        self.stats.forced_nodes.set(self.rps.forced_nodes);
        self.stats.down.set(self.rps.ledger().down());
        let fs = self.rps.forecast_stats();
        self.stats.forecast_mae.set(fs.and_then(|s| s.mae()));
        self.stats.pregrant_hit_rate.set(fs.and_then(|s| s.hit_rate()));
    }
}

impl Service for RpsSvc {
    fn name(&self) -> &str {
        "resource-provision-service"
    }

    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_>) {
        let now = ctx.now();
        match msg {
            Msg::Claim { dept, nodes } => {
                let d = self.rps.request(dept, nodes, now);
                if d.from_free > 0 {
                    ctx.send_to_dept(dept, Msg::Grant { dept, nodes: d.from_free });
                }
                for &(victim, m) in &d.force {
                    self.pending_force.push_back((victim, dept));
                    ctx.send_to_dept(victim, Msg::ForceReturn { dept: victim, nodes: m });
                }
                // only service-side refusals count: a batch department
                // re-claims its standing backlog every tick (that is how
                // it discovers freed lease capacity), so counting those
                // would inflate `denied` with the same unmet need each
                // tick — the virtual-time path's denied counters are
                // service-side only too
                if d.denied > 0 && self.roster.get(&dept) == Some(&DeptKind::Service) {
                    self.stats.denied.set(self.stats.denied.get() + d.denied);
                }
            }
            Msg::Release { dept, nodes } => {
                self.rps.release(dept, nodes, now);
                self.provision_idle_to_batch(ctx);
            }
            Msg::Released { dept, nodes, .. } => {
                if let Some(i) = self.leaving.iter().position(|&d| d == dept) {
                    // the final return of a departing CMS: everything goes
                    // back to the free pool and the department is dropped
                    self.leaving.swap_remove(i);
                    self.rps.leave(dept, now);
                    self.roster.remove(&dept);
                    self.stats.leaves.set(self.stats.leaves.get() + 1);
                    self.provision_idle_to_batch(ctx);
                } else if let Some(i) =
                    self.pending_force.iter().position(|&(v, _)| v == dept)
                {
                    let (victim, claimant) =
                        // phoenix-lint: allow(panic_path): index came from position() on this deque
                        self.pending_force.remove(i).expect("position just found");
                    self.rps.complete_force(victim, claimant, nodes, now);
                    ctx.send_to_dept(claimant, Msg::Grant { dept: claimant, nodes });
                } else {
                    // an unsolicited return conserves nodes as a release
                    self.rps.release(dept, nodes, now);
                }
            }
            Msg::LeaseReturn { dept, returned, renewed } => {
                self.rps.lease_return(dept, returned, renewed, now);
                // the freed capacity stays in the pool for urgent service
                // claims; batch departments with queued work re-claim it
                // on their next tick (arXiv:1006.1401's point)
            }
            Msg::DeptJoin { dept, kind, quota } => {
                let tier = match kind {
                    DeptKind::Service => 0,
                    DeptKind::Batch => 1,
                };
                self.rps.join(DeptProfile { id: dept, kind, tier, quota }, now);
                self.roster.insert(dept, kind);
                self.stats.joins.set(self.stats.joins.get() + 1);
            }
            Msg::DeptLeave { dept } => {
                let held = self.rps.ledger().held(dept);
                if held > 0 {
                    self.leaving.push(dept);
                    ctx.send_to_dept(dept, Msg::ForceReturn { dept, nodes: held });
                } else {
                    self.rps.leave(dept, now);
                    self.roster.remove(&dept);
                    self.stats.leaves.set(self.stats.leaves.get() + 1);
                }
            }
            Msg::NodeDown { nodes, .. } => {
                // injected with the placeholder address DeptId::RPS_FAULT:
                // the RPS picks the victims (free pool first, else the
                // largest holder), books the down move, and forwards the
                // crash dept-addressed to each hit CMS
                self.stats.crashes.set(self.stats.crashes.get() + 1);
                for (holder, n) in self.rps.crash_anywhere(nodes, now) {
                    if let Some(d) = holder {
                        ctx.send_to_dept(d, Msg::NodeDown { dept: d, nodes: n });
                    }
                }
            }
            Msg::NodeUp { nodes, .. } => {
                self.stats.recovers.set(self.stats.recovers.get() + 1);
                self.rps.recover(nodes, now);
                // repaired nodes land in the free pool; idle capacity flows
                // back to the batch members at once, service deficits
                // re-claim on their next tick
                self.provision_idle_to_batch(ctx);
            }
            Msg::Tick { now } => {
                // demand sample for forecasting policies: the ledger's
                // holdings are the serve-path demand signal (a satisfied
                // service department holds exactly its scaler target), so
                // the DemandTracker sees the same per-tick series shape as
                // the virtual-time coordinator's on_ws_demand hook
                let service: Vec<DeptId> = self
                    .roster
                    .iter()
                    .filter(|&(_, &k)| k == DeptKind::Service)
                    .map(|(&d, _)| d)
                    .collect();
                for d in service {
                    let held = self.rps.ledger().held(d);
                    let util = if held == 0 { 0.0 } else { 1.0 };
                    self.rps.observe(d, util, held, now);
                }
                // lease expiry rides the tick: each expired lease becomes a
                // LeaseExpired/LeaseReturn exchange with the holder
                for (d, n) in self.rps.lease_expirations(now) {
                    ctx.send_to_dept(d, Msg::LeaseExpired { dept: d, nodes: n });
                }
            }
            _ => {}
        }
        self.sync();
    }
}

// ---- the batch CMS service ---------------------------------------------------

struct BatchSvc {
    dept: DeptId,
    st: StServer,
    jobs: Arc<[Job]>,
    next_job: usize,
    /// Trace indices admitted early via [`Msg::SubmitJob`] (always ≥
    /// `next_job`): the tick arrival loop skips them so a job is never
    /// admitted twice.
    submitted_early: BTreeSet<usize>,
    /// (finish_time, job_id) pending completions, processed on ticks.
    finishes: Vec<(u64, u64)>,
    /// Ingress submissions awaiting their ack, keyed by job id:
    /// `(trace_idx, submitted_at)`. Entries leave as jobs are scheduled
    /// (emitting a [`SubmitAck`]); jobs killed before ever starting simply
    /// never ack — the frontend counts acks ≤ ingested.
    ack_pending: BTreeMap<u64, (usize, u64)>,
    rps: ServiceId,
    monitor: ServiceId,
    me: ServiceId,
    stats: Rc<DeptStats>,
}

impl BatchSvc {
    fn schedule(&mut self, now: u64, ctx: &mut Ctx<'_>) {
        for s in self.st.schedule(now) {
            self.finishes.push((s.finish_at, s.job_id));
            // a job that came in over the network frontend acks the moment
            // it is first scheduled onto granted nodes
            if let Some((trace_idx, submitted)) = self.ack_pending.remove(&s.job_id) {
                ctx.ack(SubmitAck { dept: self.dept, trace_idx, submitted, granted: now });
            }
        }
    }

    /// Record `n` freshly killed jobs (the counters update incrementally —
    /// cheap Cell writes, not an outcomes rescan per message).
    fn count_killed(&self, n: usize) {
        self.stats.killed.set(self.stats.killed.get() + n as u64);
    }

    /// Record the completion the CMS just pushed onto its outcomes.
    fn count_completed(&self) {
        debug_assert!(matches!(
            self.st.outcomes.last().map(|o| o.state),
            Some(JobState::Completed)
        ));
        if let Some(o) = self.st.outcomes.last() {
            self.stats.completed.set(self.stats.completed.get() + 1);
            self.stats
                .turnaround_sum
                .set(self.stats.turnaround_sum.get() + o.turnaround() as f64);
        }
    }

    fn sync(&self) {
        // jobs not yet admitted at the horizon count as in flight, so the
        // accounting completed + killed + in_flight == submitted closes
        // (`submitted_early` holds only indices the arrival cursor hasn't
        // passed, so the subtraction never underflows)
        self.stats.in_flight.set(
            self.st.in_flight() + (self.jobs.len() - self.next_job)
                - self.submitted_early.len(),
        );
        self.stats.holding.set(self.st.pool());
    }
}

impl Service for BatchSvc {
    fn name(&self) -> &str {
        "st-server"
    }

    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_>) {
        match msg {
            Msg::Grant { nodes, .. } => {
                self.st.grant(nodes);
                let now = ctx.now();
                self.schedule(now, ctx);
            }
            Msg::ForceReturn { nodes, .. } => {
                let killed = self.st.force_return(nodes, ctx.now());
                self.count_killed(killed.len());
                if let Some(sender) = ctx.sender().service() {
                    ctx.send(sender, Msg::Released {
                        dept: self.dept,
                        nodes,
                        killed: killed.len() as u64,
                    });
                }
            }
            Msg::LeaseExpired { nodes, .. } => {
                // return what is idle, renew what is demonstrably busy
                let returned = nodes.min(self.st.idle());
                if returned > 0 {
                    let killed = self.st.force_return(returned, ctx.now());
                    debug_assert!(killed.is_empty(), "lease reclaim must only take idle nodes");
                    self.count_killed(killed.len());
                }
                let busy = self.st.pool() - self.st.idle();
                let renewed = (nodes - returned).min(busy);
                if let Some(sender) = ctx.sender().service() {
                    ctx.send(sender, Msg::LeaseReturn { dept: self.dept, returned, renewed });
                }
            }
            Msg::SubmitJob { trace_idx, .. } => {
                if trace_idx < self.next_job || self.submitted_early.contains(&trace_idx) {
                    log::warn!(
                        "{}: SubmitJob index {trace_idx} already admitted — dropped",
                        self.dept
                    );
                } else if let Some(job) = self.jobs.get(trace_idx) {
                    let job = job.clone();
                    self.submitted_early.insert(trace_idx);
                    // frontend-injected submissions owe an ack when their
                    // covering grant lands
                    if ctx.sender() == Sender::Ingress {
                        self.ack_pending.insert(job.id, (trace_idx, ctx.now()));
                    }
                    self.st.submit(job);
                    let now = ctx.now();
                    self.schedule(now, ctx);
                } else {
                    log::warn!(
                        "{}: SubmitJob index {trace_idx} beyond trace ({} jobs) — dropped",
                        self.dept,
                        self.jobs.len()
                    );
                }
            }
            Msg::NodeDown { nodes, .. } => {
                // the RPS already booked the nodes into the down pool; this
                // CMS just loses them — killing whatever was running on them
                let killed = self.st.crash(nodes, ctx.now());
                self.count_killed(killed.len());
            }
            Msg::Tick { now } => {
                // retire due completions
                let mut done = Vec::new();
                self.finishes.retain(|&(t, id)| {
                    if t <= now {
                        done.push(id);
                        false
                    } else {
                        true
                    }
                });
                for id in done {
                    if self.st.finish(id, now) {
                        self.count_completed();
                    }
                }
                // admit newly arrived jobs (skipping any the client tools
                // already pushed through SubmitJob)
                while self.next_job < self.jobs.len()
                    && self.jobs[self.next_job].submit <= now
                {
                    if !self.submitted_early.remove(&self.next_job) {
                        self.st.submit(self.jobs[self.next_job].clone());
                    }
                    self.next_job += 1;
                }
                self.schedule(now, ctx);
                // batch resource-management policy, serve-path flavor: ask
                // upstream for the queued work the idle pool cannot cover
                // (a no-op under the cooperative policy, whose free pool is
                // always drained; lease/static/proportional policies grant
                // from the pool per their contracts)
                let need = self.st.queued_nodes().saturating_sub(self.st.idle());
                if need > 0 {
                    ctx.send(self.rps, Msg::Claim { dept: self.dept, nodes: need });
                }
                ctx.send(self.monitor, Msg::Heartbeat { from: self.me, now });
            }
            _ => {}
        }
        self.sync();
    }
}

// ---- the service CMS service -------------------------------------------------

struct ServiceSvc {
    dept: DeptId,
    ws: WsServer,
    scaler: ScalerFn,
    rates: RateSeries,
    cap: f64,
    rps: ServiceId,
    monitor: ServiceId,
    me: ServiceId,
    stats: Rc<DeptStats>,
}

impl ServiceSvc {
    fn sync(&self) {
        self.stats.holding.set(self.ws.holding());
        self.stats.shortage.set(self.ws.shortage_node_secs);
    }
}

impl Service for ServiceSvc {
    fn name(&self) -> &str {
        "ws-server"
    }

    fn handle(&mut self, msg: Msg, ctx: &mut Ctx<'_>) {
        match msg {
            Msg::Tick { now } => {
                let rate = self.rates.at(now);
                let held = self.ws.holding().max(1);
                let util = utilization(rate, held, self.cap);
                let target = (self.scaler)(util, rate);
                self.stats.peak_demand.set(self.stats.peak_demand.get().max(target));
                match self.ws.set_demand(target, now) {
                    WsAction::None => {}
                    WsAction::Release(n) => {
                        self.ws.release(n);
                        ctx.send(self.rps, Msg::Release { dept: self.dept, nodes: n });
                    }
                    WsAction::Request(n) => {
                        ctx.send(self.rps, Msg::Claim { dept: self.dept, nodes: n })
                    }
                }
                ctx.send(self.monitor, Msg::Heartbeat { from: self.me, now });
            }
            Msg::Grant { nodes, .. } => self.ws.grant(nodes),
            Msg::ForceReturn { nodes, .. } => {
                // a service department surrenders at most what it holds
                // (only reachable under custom policies that name service
                // victims — the built-ins never do)
                let give = nodes.min(self.ws.holding());
                if give > 0 {
                    self.ws.release(give);
                }
                if let Some(sender) = ctx.sender().service() {
                    ctx.send(sender, Msg::Released { dept: self.dept, nodes: give, killed: 0 });
                }
            }
            Msg::NodeDown { nodes, .. } => {
                // effective capacity shrinks without the demand target
                // moving; the next tick's demand evaluation re-claims
                self.ws.crash(nodes.min(self.ws.holding()), ctx.now());
            }
            _ => {}
        }
        self.sync();
    }
}

// ---- the monitor service -----------------------------------------------------

struct MonitorSvc {
    monitor: Rc<RefCell<Monitor>>,
}

impl Service for MonitorSvc {
    fn name(&self) -> &str {
        "heartbeat-monitor"
    }

    fn handle(&mut self, msg: Msg, _ctx: &mut Ctx<'_>) {
        if let Msg::Heartbeat { from, now } = msg {
            self.monitor.borrow_mut().beat(from, now);
        }
    }
}

// ---- the serve loop ----------------------------------------------------------

/// Summary of a realtime run — the serve-path mirror of the virtual-time
/// [`super::RunResult`], with the same per-department breakdown shape.
#[derive(Debug)]
pub struct ServeReport {
    pub label: String,
    pub cluster_nodes: u64,
    pub sim_seconds: u64,
    /// Wall-clock duration of the run. The deterministic serve loop never
    /// reads the wall clock (lint rule R1): this is left at
    /// [`Duration::ZERO`] by [`serve_config`] and stamped by the CLI
    /// boundary (`cmd_serve`), the one place that may time the call.
    pub wall: Duration,
    pub ticks: u64,
    pub messages: u64,
    pub submitted: usize,
    pub completed: u64,
    pub killed: u64,
    /// Jobs still queued/running (or not yet admitted) at the horizon.
    pub in_flight: usize,
    /// Average turnaround of completed jobs, seconds.
    pub avg_turnaround: f64,
    /// Unmet service demand, node-seconds, summed over service depts.
    pub ws_shortage_node_secs: u64,
    /// Highest instance target any service department asked for.
    pub ws_peak_demand: u64,
    pub force_returns: u64,
    pub forced_nodes: u64,
    /// Service-side demand the policy refused (non-cooperative baselines
    /// only; a batch department's standing per-tick backlog claims are
    /// not counted).
    pub denied: u64,
    /// Free-pool size when the loop ended (conservation check:
    /// `free_end + Σ per_dept.holding_end + down_end == cluster_nodes`).
    pub free_end: u64,
    /// Runtime affiliation events processed.
    pub joins: u64,
    pub leaves: u64,
    /// Fault injections processed ([`Msg::NodeDown`] / [`Msg::NodeUp`]).
    pub crashes: u64,
    pub recovers: u64,
    /// Nodes still in the ledger's down pool at the horizon.
    pub down_end: u64,
    /// Services whose heartbeat was overdue at the horizon.
    pub down_services: Vec<String>,
    /// Network-frontend requests accepted into the ingest queue (0 when
    /// the run had no frontend).
    pub ingested: u64,
    /// Requests shed 429-style because the bounded ingest queue was full.
    pub shed: u64,
    /// Undecodable request lines plus requests addressing departments the
    /// roster could not route.
    pub ingest_bad: u64,
    /// [`SubmitAck`]s delivered back to the frontend (≤ `ingested`: jobs
    /// killed before first scheduling never ack).
    pub acked: u64,
    /// Mean bus round-trip (submit → first scheduling) over acked
    /// requests, trace seconds.
    pub grant_latency_mean_s: f64,
    /// p99 of the same distribution.
    pub grant_latency_p99_s: f64,
    /// Forecast mean absolute error, nodes (forecasting policies only).
    pub forecast_mae: Option<f64>,
    /// Share of targeted service claims served wholly from the reserved
    /// free pool (forecasting policies only).
    pub pregrant_hit_rate: Option<f64>,
    /// Per-department breakdown, in department-id order (leavers report
    /// their final state).
    pub per_dept: Vec<DeptSummary>,
}

/// Per-department driver bookkeeping, indexed by `DeptId` (joiners append).
#[derive(Default)]
struct RosterState {
    specs: Vec<DeptSpec>,
    stats: Vec<Rc<DeptStats>>,
    service_ids: Vec<ServiceId>,
    active: Vec<DeptId>,
    pending_leaves: Vec<(u64, DeptId)>,
    submitted: usize,
}

/// Immutable wiring every CMS service needs.
struct Wiring {
    rps: ServiceId,
    monitor: ServiceId,
    cap: f64,
    scheduler: crate::config::SchedulerKind,
    kill_order: crate::config::KillOrder,
    /// Noisy-neighbor throughput factor for batch servers (1.0 when the
    /// roster is not genuinely shared — exactly inert).
    efficiency: f64,
}

/// Box one department's CMS, bind it in the bus directory, and record the
/// driver-side bookkeeping. Boot members pass their pre-granted servers;
/// runtime joiners pass `None` and start empty (they claim on their first
/// tick).
fn register_cms(
    bus: &mut Bus,
    wiring: &Wiring,
    state: &mut RosterState,
    dept: DeptId,
    d: ServeDept,
    st: Option<StServer>,
    ws: Option<WsServer>,
) -> Result<()> {
    let share = Rc::new(DeptStats::default());
    let me = bus.len_services();
    let svc: Box<dyn Service> = match d.workload {
        ServeWorkload::Batch(jobs) => {
            state.submitted += jobs.len();
            let mut st = st.unwrap_or_else(|| {
                StServer::for_dept(dept, wiring.scheduler, wiring.kill_order)
            });
            if wiring.efficiency != 1.0 {
                st.set_efficiency(wiring.efficiency);
            }
            Box::new(BatchSvc {
                dept,
                st,
                jobs,
                next_job: 0,
                submitted_early: BTreeSet::new(),
                finishes: Vec::new(),
                ack_pending: BTreeMap::new(),
                rps: wiring.rps,
                monitor: wiring.monitor,
                me,
                stats: Rc::clone(&share),
            })
        }
        ServeWorkload::Service { rates, scaler, .. } => Box::new(ServiceSvc {
            dept,
            ws: ws.unwrap_or_else(|| WsServer::for_dept(dept)),
            scaler,
            rates,
            cap: wiring.cap,
            rps: wiring.rps,
            monitor: wiring.monitor,
            me,
            stats: Rc::clone(&share),
        }),
    };
    let id = bus
        .register_dept(dept, svc)
        .with_context(|| format!("registering {dept}"))?;
    debug_assert_eq!(id, me);
    if let Some(t) = d.leave_at {
        state.pending_leaves.push((t, dept));
    }
    state.specs.push(d.spec);
    state.stats.push(share);
    state.service_ids.push(id);
    state.active.push(dept);
    Ok(())
}

/// Run the live coordinator over an explicit roster for `sim_seconds` of
/// trace time at `speedup`× wall clock (0 = as fast as possible), under
/// any [`PolicyChoice`] built from the boot members' profiles.
///
/// Departments with `spec.join_at > 0` join mid-run ([`Msg::DeptJoin`]);
/// [`ServeDept::leave_at`] departures are reclaimed over the bus
/// ([`Msg::DeptLeave`]). Bus protocol failures (livelock, routing to a
/// department that never joined) surface as typed
/// [`crate::services::BusError`]s in the `anyhow` chain — the serve-path
/// mirror of the sim's `SimError`.
pub fn serve_roster(
    cfg: &ExperimentConfig,
    policy: &PolicyChoice,
    depts: Vec<ServeDept>,
    sim_seconds: u64,
    speedup: u64,
) -> Result<ServeReport> {
    serve_roster_with_ingest(cfg, policy, depts, sim_seconds, speedup, None)
}

/// [`serve_roster`] with an optional network frontend
/// ([`crate::net::ServeFrontend`]): each tick, due external requests are
/// pumped through the frontend's bounded queue (shedding 429-style when
/// full) and posted as ingress-sent [`Msg::SubmitJob`]s; acks drained
/// from the bus flow back through the frontend and into the report's
/// grant-latency figures. With `None` the ingest path is exactly inert —
/// no ingress posts, no acks, bit-identical to [`serve_roster`].
pub fn serve_roster_with_ingest(
    cfg: &ExperimentConfig,
    policy: &PolicyChoice,
    depts: Vec<ServeDept>,
    sim_seconds: u64,
    speedup: u64,
    mut frontend: Option<&mut crate::net::ServeFrontend>,
) -> Result<ServeReport> {
    let tick_step = cfg.ws_sample_period;
    if tick_step == 0 {
        bail!("ws_sample_period must be positive");
    }
    // noisy neighbors degrade batch throughput only on a genuinely shared
    // cluster (both kinds present somewhere in the roster)
    let shared = depts.iter().any(|d| d.spec.kind == DeptKind::Batch)
        && depts.iter().any(|d| d.spec.kind == DeptKind::Service);
    // boot members keep input order; joiners follow, sorted by arrival —
    // ids are dense in that combined order, matching Rps::join's contract
    let (mut boot, mut joiners): (Vec<ServeDept>, Vec<ServeDept>) =
        depts.into_iter().partition(|d| d.spec.join_at == 0);
    joiners.sort_by_key(|d| d.spec.join_at);
    if boot.is_empty() {
        bail!("at least one department must be present at boot (join_at = 0)");
    }
    for d in &joiners {
        if let Some(leave) = d.leave_at {
            if leave <= d.spec.join_at {
                bail!("department '{}': leave_at must be after join_at", d.spec.name);
            }
        }
    }

    let total = cfg.total_nodes;
    let profiles: Vec<DeptProfile> = boot
        .iter()
        .enumerate()
        .map(|(i, d)| d.spec.profile(DeptId(i as u16)))
        .collect();
    let mut rps = Rps::new(total, boot.len(), policy.build(&profiles));
    let label = format!("serve-K{}-{}", boot.len() + joiners.len(), policy.name());

    // ---- boot: mirror the virtual-time sim — each boot service dept gets
    // its boot-instances grant, the batch depts split the rest
    let cap = cfg.web.instance_capacity_rps;
    let mut boot_servers: Vec<Option<WsServer>> = Vec::with_capacity(boot.len());
    let mut boot_batch: Vec<Option<StServer>> = Vec::with_capacity(boot.len());
    for (i, d) in boot.iter().enumerate() {
        let id = DeptId(i as u16);
        match &d.workload {
            ServeWorkload::Service { boot_instances, .. } => {
                let granted = rps.bootstrap_grant(id, *boot_instances);
                let mut ws = WsServer::for_dept(id);
                ws.grant(granted);
                ws.set_demand(*boot_instances, 0);
                boot_servers.push(Some(ws));
                boot_batch.push(None);
            }
            ServeWorkload::Batch(_) => {
                boot_servers.push(None);
                boot_batch.push(Some(StServer::for_dept(id, cfg.scheduler, cfg.kill_order)));
            }
        }
    }
    let batch_ids: Vec<DeptId> = boot
        .iter()
        .enumerate()
        .filter(|(_, d)| matches!(d.workload, ServeWorkload::Batch(_)))
        .map(|(i, _)| DeptId(i as u16))
        .collect();
    for (d, n) in rps.provision_idle(&batch_ids, 0) {
        boot_batch[d.index()]
            .as_mut()
            // phoenix-lint: allow(panic_path): provision_idle was given only batch ids
            .expect("idle grants target batch departments")
            .grant(n);
    }
    let boot_holdings: Vec<u64> = boot_batch
        .iter()
        .zip(&boot_servers)
        .map(|(st, ws)| match (st, ws) {
            (Some(st), _) => st.pool(),
            (_, Some(ws)) => ws.holding(),
            _ => 0,
        })
        .collect();

    // ---- wire the bus: rps, monitor, then one CMS per boot department
    let mut bus = Bus::new();
    let rps_stats = Rc::new(RpsStats::default());
    rps_stats.free.set(rps.ledger().free());
    let roster: BTreeMap<DeptId, DeptKind> = boot
        .iter()
        .enumerate()
        .map(|(i, d)| (DeptId(i as u16), d.spec.kind))
        .collect();
    let rps_id = bus.register(Box::new(RpsSvc {
        rps,
        roster,
        pending_force: VecDeque::new(),
        leaving: Vec::new(),
        stats: Rc::clone(&rps_stats),
    }));
    let monitor = Rc::new(RefCell::new(Monitor::new(2 * tick_step)));
    let mon_id = bus.register(Box::new(MonitorSvc { monitor: Rc::clone(&monitor) }));

    let mut state = RosterState::default();
    let wiring = Wiring {
        rps: rps_id,
        monitor: mon_id,
        cap,
        scheduler: cfg.scheduler,
        kill_order: cfg.kill_order,
        efficiency: if shared { cfg.faults.efficiency } else { 1.0 },
    };
    for (i, d) in boot.drain(..).enumerate() {
        let id = DeptId(i as u16);
        let st = boot_batch[i].take();
        let ws = boot_servers[i].take();
        register_cms(&mut bus, &wiring, &mut state, id, d, st, ws)?;
        // seed the report cells with the boot allocation (the first sync
        // happens on the department's first handled message)
        if let Some(&h) = boot_holdings.get(i) {
            state.stats[i].holding.set(h);
        }
    }
    let n_boot = state.stats.len();

    // ---- the tick loop
    // the deterministic fault schedule (empty when faults are disabled):
    // due crashes/recoveries are injected at the RPS each tick, before the
    // lease settling and the department ticks, with the placeholder fault
    // address — the serve-path twin of the sim's NodeCrash/NodeRecover
    // events (quantized to tick boundaries)
    let fault_events = crate::faults::schedule(&cfg.faults, sim_seconds, total);
    let mut next_fault = 0usize;
    let limit = 10_000u64.max(100 * (n_boot as u64 + joiners.len() as u64 + 2));
    // Wall-clock anchor for optional realtime pacing (`--speedup N`): the
    // sleep at the bottom of the loop only *delays* execution; virtual time
    // (`now`) drives every simulated decision, so determinism is untouched.
    // Regression note: this read previously sat unconditionally on the tick
    // path and leaked into ServeReport.wall — see ARCHITECTURE.md
    // §"Determinism contract".
    #[allow(clippy::disallowed_methods)] // Instant::now — same pacing-only justification
    // phoenix-lint: allow(wall_clock): pacing-only anchor, gated on speedup; no simulated state reads it
    let pacing_anchor = (speedup > 0).then(Instant::now);
    let mut ticks = 0u64;
    let mut now = 0u64;
    let mut next_join = 0usize;
    // per-request bus round-trip latencies (trace seconds) of every ack
    // the frontend received; empty without a frontend
    let mut grant_latencies: Vec<f64> = Vec::new();
    state.pending_leaves.sort_by_key(|&(t, _)| t);
    let mut joiners = joiners.into_iter().collect::<VecDeque<_>>();
    while now <= sim_seconds {
        bus.set_now(now);
        // runtime arrivals due by this tick join before anyone ticks: the
        // RPS must know the department before its first claim routes
        while joiners.front().is_some_and(|d| d.spec.join_at <= now) {
            // phoenix-lint: allow(panic_path): front() checked is_some by the loop guard
            let d = joiners.pop_front().expect("front just checked");
            let dept = DeptId((n_boot + next_join) as u16);
            next_join += 1;
            bus.post(rps_id, Msg::DeptJoin {
                dept,
                kind: d.spec.kind,
                quota: d.spec.quota,
            });
            register_cms(&mut bus, &wiring, &mut state, dept, d, None, None)?;
            bus.run_until_quiescent(limit)
                .with_context(|| format!("DeptJoin of {dept} at t={now}s"))?;
        }
        // due fault events fire in schedule order (crash before the paired
        // recovery, always)
        while fault_events.get(next_fault).is_some_and(|ev| ev.at <= now) {
            let ev = &fault_events[next_fault];
            next_fault += 1;
            let msg = match ev.kind {
                crate::faults::FaultKind::Crash => {
                    Msg::NodeDown { dept: DeptId::RPS_FAULT, nodes: 1 }
                }
                crate::faults::FaultKind::Recover => {
                    Msg::NodeUp { dept: DeptId::RPS_FAULT, nodes: 1 }
                }
            };
            bus.post(rps_id, msg);
            bus.run_until_quiescent(limit)
                .with_context(|| format!("fault event at t={now}s"))?;
        }
        // due external requests enter next: the frontend's bounded queue
        // releases at most its drain budget per tick, each becoming an
        // ingress-sent SubmitJob; a request for a department that never
        // joined (or already left) is counted, not silently dropped
        if let Some(fe) = frontend.as_deref_mut() {
            let mut posted = false;
            for req in fe.pump(now) {
                let msg = Msg::SubmitJob { dept: req.dept, trace_idx: req.trace_idx };
                if bus.post_to_dept_ingress(req.dept, msg).is_err() {
                    fe.count_unroutable();
                } else {
                    posted = true;
                }
            }
            if posted {
                bus.run_until_quiescent(limit)
                    .with_context(|| format!("ingest drain at t={now}s"))?;
            }
        }
        // the RPS settles lease expiries on its tick…
        bus.post(rps_id, Msg::Tick { now });
        bus.run_until_quiescent(limit)
            .with_context(|| format!("RPS tick at t={now}s"))?;
        // …then each department ticks in id order, one quiescent dispatch
        // each — the bus mirror of the sim's same-timestamp event atomicity
        for &d in &state.active {
            bus.post_to_dept(d, Msg::Tick { now })
                .with_context(|| format!("ticking {d} at t={now}s"))?;
            bus.run_until_quiescent(limit)
                .with_context(|| format!("tick of {d} at t={now}s"))?;
        }
        // departures settle at the end of their tick
        while state.pending_leaves.first().is_some_and(|&(t, _)| t <= now) {
            let (_, dept) = state.pending_leaves.remove(0);
            bus.post(rps_id, Msg::DeptLeave { dept });
            bus.run_until_quiescent(limit)
                .with_context(|| format!("DeptLeave of {dept} at t={now}s"))?;
            bus.unbind_dept(dept);
            state.active.retain(|&x| x != dept);
            monitor.borrow_mut().forget(state.service_ids[dept.index()]);
        }
        // acks minted this tick (idle-pool admissions, grants, tick-time
        // scheduling) leave the bus toward the clients now, and their
        // bus round-trip latency is recorded per request
        if let Some(fe) = frontend.as_deref_mut() {
            for ack in bus.take_acks() {
                grant_latencies.push(ack.granted.saturating_sub(ack.submitted) as f64);
                fe.deliver_ack(&ack);
            }
        }
        ticks += 1;
        now += tick_step;
        if let Some(anchor) = pacing_anchor {
            let wall_target = Duration::from_secs_f64(now as f64 / speedup as f64);
            let elapsed = anchor.elapsed();
            if wall_target > elapsed {
                std::thread::sleep(wall_target - elapsed);
            }
        }
    }
    let RosterState { specs, stats, submitted, .. } = state;
    let (ingested, shed, ingest_bad) = frontend
        .as_ref()
        .map(|fe| (fe.stats.ingested, fe.stats.shed, fe.stats.bad))
        .unwrap_or((0, 0, 0));

    // ---- report
    let last_now = now - tick_step;
    let down_services: Vec<String> = monitor
        .borrow()
        .down(last_now)
        .into_iter()
        .map(|id| bus.service_name(id).to_string())
        .collect();
    let mut per_dept = Vec::with_capacity(specs.len());
    let mut completed = 0u64;
    let mut killed = 0u64;
    let mut in_flight = 0usize;
    let mut shortage = 0u64;
    let mut peak = 0u64;
    let mut turnaround_sum = 0.0f64;
    for (spec, s) in specs.iter().zip(&stats) {
        completed += s.completed.get();
        killed += s.killed.get();
        in_flight += s.in_flight.get();
        shortage += s.shortage.get();
        peak = peak.max(s.peak_demand.get());
        turnaround_sum += s.turnaround_sum.get();
        let dc = s.completed.get();
        per_dept.push(DeptSummary {
            name: spec.name.clone(),
            kind: spec.kind,
            completed: dc,
            killed: s.killed.get(),
            in_flight: s.in_flight.get(),
            avg_turnaround: if dc > 0 { s.turnaround_sum.get() / dc as f64 } else { 0.0 },
            shortage_node_secs: s.shortage.get(),
            holding_end: s.holding.get(),
        });
    }
    Ok(ServeReport {
        label,
        cluster_nodes: total,
        sim_seconds,
        wall: Duration::ZERO, // stamped by the CLI boundary, see ServeReport::wall
        ticks,
        messages: bus.delivered,
        submitted,
        completed,
        killed,
        in_flight,
        avg_turnaround: if completed > 0 { turnaround_sum / completed as f64 } else { 0.0 },
        ws_shortage_node_secs: shortage,
        ws_peak_demand: peak,
        force_returns: rps_stats.force_returns.get(),
        forced_nodes: rps_stats.forced_nodes.get(),
        denied: rps_stats.denied.get(),
        free_end: rps_stats.free.get(),
        joins: rps_stats.joins.get(),
        leaves: rps_stats.leaves.get(),
        crashes: rps_stats.crashes.get(),
        recovers: rps_stats.recovers.get(),
        down_end: rps_stats.down.get(),
        down_services,
        ingested,
        shed,
        ingest_bad,
        acked: crate::util::num::u64_from_usize(grant_latencies.len()),
        grant_latency_mean_s: crate::util::stats::mean(&grant_latencies),
        grant_latency_p99_s: crate::util::stats::percentile(&grant_latencies, 0.99),
        forecast_mae: rps_stats.forecast_mae.get(),
        pregrant_hit_rate: rps_stats.pregrant_hit_rate.get(),
        per_dept,
    })
}

/// Build and run the serve roster a config describes: its
/// `[[department]]` entries (the paper's ST+WS pair when none are
/// declared), the `[policy]` section (cooperative by default), the
/// synthetic/archive traces of the trace layer, and any `join_at`
/// arrivals. `scaler_for` supplies each service department's scaling
/// brain (reactive, predictive, …).
pub fn serve_config(
    cfg: &ExperimentConfig,
    sim_seconds: u64,
    speedup: u64,
    scaler_for: impl FnMut(&DeptSpec, &ExperimentConfig) -> ScalerFn,
) -> Result<ServeReport> {
    serve_config_with_ingest(cfg, sim_seconds, speedup, scaler_for, None)
}

/// [`serve_config`] with an optional network frontend — the `phoenixd
/// serve --listen` / `--ingest-file` entry point. See
/// [`serve_roster_with_ingest`].
pub fn serve_config_with_ingest(
    cfg: &ExperimentConfig,
    sim_seconds: u64,
    speedup: u64,
    mut scaler_for: impl FnMut(&DeptSpec, &ExperimentConfig) -> ScalerFn,
    frontend: Option<&mut crate::net::ServeFrontend>,
) -> Result<ServeReport> {
    let specs = if cfg.departments.is_empty() {
        RosterMix::Alternating.departments(2, cfg)
    } else {
        cfg.departments.clone()
    };
    let traces = crate::experiments::scale::build_traces(&specs, cfg)?;
    let depts: Vec<ServeDept> = specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let workload = match spec.kind {
                DeptKind::Batch => ServeWorkload::Batch(
                    // phoenix-lint: allow(panic_path): build_traces builds a job trace per batch dept
                    traces.batch_jobs(i).expect("batch departments carry a job trace"),
                ),
                DeptKind::Service => ServeWorkload::Service {
                    rates: traces
                        .service_rates(i)
                        // phoenix-lint: allow(panic_path): build_traces builds a rate series per service dept
                        .expect("service departments carry a rate series"),
                    scaler: scaler_for(spec, cfg),
                    boot_instances: traces.service_boot_instances(i).unwrap_or(1),
                },
            };
            ServeDept {
                spec: spec.clone(),
                workload,
                // the roster's leave_at axis drives serve-path departures
                leave_at: (spec.leave_at > 0).then_some(spec.leave_at),
            }
        })
        .collect();
    let policy = cfg
        .policy
        .clone()
        .unwrap_or(PolicyChoice::Base(PolicySpec::Cooperative));
    serve_roster_with_ingest(cfg, &policy, depts, sim_seconds, speedup, frontend)
}

/// Convenience constructor for the paper's two-department testbed run:
/// one ST-like batch department over `jobs`, one WS-like service
/// department over `rates` + `scaler`, cooperative policy — the serve
/// mirror of [`super::ConsolidationSim::new`].
pub fn serve_pair(
    cfg: &ExperimentConfig,
    jobs: Vec<Job>,
    rates: RateSeries,
    scaler: ScalerFn,
    sim_seconds: u64,
    speedup: u64,
) -> Result<ServeReport> {
    let depts = vec![
        ServeDept::batch("st", cfg.st_nodes, jobs),
        ServeDept::service("ws", cfg.ws_nodes, rates, scaler),
    ];
    serve_roster(
        cfg,
        &PolicyChoice::Base(PolicySpec::Cooperative),
        depts,
        sim_seconds,
        speedup,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::wscms::autoscaler::Reactive;

    fn reactive_scaler(max: u64) -> ScalerFn {
        let mut reactive = Reactive::new(max);
        Box::new(move |util, _| reactive.decide(util))
    }

    #[test]
    fn serve_pair_runs_and_routes_messages() {
        let mut cfg = ExperimentConfig::dynamic(64);
        cfg.ws_sample_period = 20;
        let rates = RateSeries { sample_period: 20, rates: vec![200.0; 100] };
        let jobs = vec![Job { id: 1, submit: 0, size: 8, runtime: 60, requested: 120 }];
        let report =
            serve_pair(&cfg, jobs, rates, reactive_scaler(64), 400, 0).unwrap();
        assert_eq!(report.ticks, 21);
        assert!(report.messages > 60, "messages={}", report.messages);
        assert_eq!(report.completed, 1);
        assert_eq!(report.submitted, 1);
        assert_eq!(report.in_flight, 0);
        assert!(report.ws_peak_demand >= 1);
        assert_eq!(report.per_dept.len(), 2);
        assert_eq!(report.per_dept[0].name, "st");
        assert_eq!(report.per_dept[0].completed, 1);
        assert_eq!(report.per_dept[1].kind, DeptKind::Service);
        // conservation against the ledger
        let held: u64 = report.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(report.free_end + held, report.cluster_nodes);
        assert!(report.down_services.is_empty(), "{:?}", report.down_services);
    }

    #[test]
    fn serve_path_faults_follow_the_deterministic_schedule() {
        let mut cfg = ExperimentConfig::dynamic(64);
        cfg.ws_sample_period = 20;
        cfg.faults.mtbf_secs = 5_000.0;
        cfg.faults.mttr_secs = 500.0;
        let mk = |cfg: &ExperimentConfig| {
            let rates = RateSeries { sample_period: 20, rates: vec![200.0; 300] };
            let jobs =
                vec![Job { id: 1, submit: 0, size: 8, runtime: 60, requested: 120 }];
            serve_pair(cfg, jobs, rates, reactive_scaler(64), 4000, 0).unwrap()
        };
        let a = mk(&cfg);
        let b = mk(&cfg);
        // the serve loop replays exactly the pure-function schedule
        let evs = crate::faults::schedule(&cfg.faults, 4000, 64);
        let want_crashes = evs
            .iter()
            .filter(|e| e.kind == crate::faults::FaultKind::Crash)
            .count() as u64;
        assert!(want_crashes > 0, "64 nodes × 4000 s at MTBF 5000 must crash");
        assert_eq!(a.crashes, want_crashes);
        assert_eq!(a.recovers, evs.len() as u64 - want_crashes);
        assert_eq!(
            (a.crashes, a.recovers, a.completed, a.killed),
            (b.crashes, b.recovers, b.completed, b.killed),
            "same seed must replay identically"
        );
        // conservation now includes the down pool
        let held: u64 = a.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(a.free_end + held + a.down_end, a.cluster_nodes, "{a:?}");
        assert!(a.down_end <= a.cluster_nodes);
    }

    #[test]
    fn predictive_policy_reports_forecast_stats_on_the_serve_path() {
        let mut cfg = ExperimentConfig::dynamic(64);
        cfg.ws_sample_period = 20;
        let mk = |policy: &PolicyChoice| {
            // a toggling load keeps the service department claiming and
            // releasing, so the tracker sees a non-constant demand series
            let rates: Vec<f64> = (0..200)
                .map(|i| if (i / 10) % 2 == 0 { 200.0 } else { 1600.0 })
                .collect();
            let depts = vec![
                ServeDept::batch(
                    "st",
                    32,
                    vec![Job { id: 1, submit: 0, size: 8, runtime: 60, requested: 600 }],
                ),
                ServeDept::service(
                    "ws",
                    32,
                    RateSeries { sample_period: 20, rates },
                    reactive_scaler(64),
                ),
            ];
            serve_roster(&cfg, policy, depts, 4000, 0).unwrap()
        };
        let predictive = mk(&PolicyChoice::Base(PolicySpec::Predictive(
            crate::provision::PredictiveSpec {
                window: 8,
                horizon_secs: 120,
                headroom_tenths: 10,
            },
        )));
        let mae = predictive.forecast_mae.expect("tracker sampled every RPS tick");
        assert!(mae.is_finite() && mae >= 0.0, "mae={mae}");
        assert!(
            predictive.pregrant_hit_rate.is_some(),
            "toggling demand must produce targeted claims: {predictive:?}"
        );
        assert_eq!(predictive.completed, 1, "{predictive:?}");
        let held: u64 = predictive.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(predictive.free_end + held, predictive.cluster_nodes);
        // non-forecasting policies stay silent on the forecast columns
        let coop = mk(&PolicyChoice::Base(PolicySpec::Cooperative));
        assert_eq!(coop.forecast_mae, None);
        assert_eq!(coop.pregrant_hit_rate, None);
    }

    #[test]
    fn mid_run_join_and_leave_flow_through_the_protocol() {
        let mut cfg = ExperimentConfig::dynamic(48);
        cfg.ws_sample_period = 20;
        let mk_jobs = |base: u64| -> Vec<Job> {
            (0..6)
                .map(|i| Job {
                    id: base + i,
                    submit: i * 20,
                    size: 4,
                    runtime: 100,
                    requested: 200,
                })
                .collect()
        };
        let rates = RateSeries { sample_period: 20, rates: vec![300.0; 200] };
        // the lease policy is what makes runtime affiliation work: the
        // anchor's idle leased capacity expires back to the free pool, so
        // the visitor's claim at join time is served without kills
        // (arXiv:1006.1401 meets arXiv:1003.0958)
        let depts = vec![
            ServeDept::batch("anchor", 32, mk_jobs(1)),
            ServeDept::service("portal", 16, rates, reactive_scaler(48)),
            // joins at t = 400 with its own backlog, leaves at t = 500
            // while still holding its granted nodes
            ServeDept::batch("visitor", 16, mk_jobs(100))
                .joining_at(400)
                .leaving_at(500),
        ];
        let report = serve_roster(
            &cfg,
            &PolicyChoice::Base(PolicySpec::Lease { secs: 200 }),
            depts,
            2000,
            0,
        )
        .unwrap();
        assert_eq!(report.joins, 1);
        assert_eq!(report.leaves, 1);
        assert_eq!(report.per_dept.len(), 3);
        let visitor = &report.per_dept[2];
        assert_eq!(visitor.name, "visitor");
        assert_eq!(visitor.holding_end, 0, "leaver must hold nothing: {report:?}");
        assert!(
            visitor.completed > 0,
            "the joiner's backlog must run between join and leave: {report:?}"
        );
        // conservation after a full join/leave cycle
        let held: u64 = report.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(report.free_end + held, report.cluster_nodes, "{report:?}");
        assert!(report.free_end > 0, "{report:?}");
        assert_eq!(report.submitted, 12);
        assert_eq!(
            report.completed as usize + report.killed as usize + report.in_flight,
            report.submitted,
            "job accounting must close: {report:?}"
        );
    }

    #[test]
    fn lease_policy_expires_idle_grants_over_the_bus() {
        let mut cfg = ExperimentConfig::dynamic(32);
        cfg.ws_sample_period = 20;
        // one short burst of work, then a long idle tail: the lease must
        // pull the idle capacity back to the free pool
        let jobs = vec![
            Job { id: 1, submit: 0, size: 8, runtime: 100, requested: 200 },
            Job { id: 2, submit: 0, size: 8, runtime: 100, requested: 200 },
        ];
        let rates = RateSeries { sample_period: 20, rates: vec![100.0; 200] };
        let depts = vec![
            ServeDept::batch("hpc", 24, jobs),
            ServeDept::service("web", 8, rates, reactive_scaler(32)),
        ];
        let report = serve_roster(
            &cfg,
            &PolicyChoice::Base(PolicySpec::Lease { secs: 200 }),
            depts,
            2000,
            0,
        )
        .unwrap();
        assert_eq!(report.completed, 2, "{report:?}");
        let batch = &report.per_dept[0];
        assert!(
            batch.holding_end < 25,
            "idle leased capacity never expired back: {report:?}"
        );
        assert!(report.free_end > 0, "{report:?}");
        let held: u64 = report.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(report.free_end + held, report.cluster_nodes);
    }

    #[test]
    fn submit_job_is_not_double_admitted() {
        struct Nop;
        impl Service for Nop {
            fn name(&self) -> &str {
                "nop"
            }
            fn handle(&mut self, _msg: Msg, _ctx: &mut Ctx<'_>) {}
        }
        let jobs: Arc<[Job]> =
            vec![Job { id: 1, submit: 40, size: 2, runtime: 60, requested: 120 }].into();
        let stats = Rc::new(DeptStats::default());
        let mut bus = Bus::new();
        let rps = bus.register(Box::new(Nop));
        let mon = bus.register(Box::new(Nop));
        let mut st = StServer::for_dept(
            DeptId(0),
            crate::config::SchedulerKind::FirstFit,
            crate::config::KillOrder::MinSizeShortestElapsed,
        );
        st.grant(8);
        bus.register_dept(DeptId(0), Box::new(BatchSvc {
            dept: DeptId(0),
            st,
            jobs,
            next_job: 0,
            submitted_early: BTreeSet::new(),
            finishes: Vec::new(),
            ack_pending: BTreeMap::new(),
            rps,
            monitor: mon,
            me: 2,
            stats: Rc::clone(&stats),
        }))
        .unwrap();
        // a client pushes job 0 ahead of its trace submit time
        bus.set_now(0);
        bus.post_to_dept(DeptId(0), Msg::SubmitJob { dept: DeptId(0), trace_idx: 0 })
            .unwrap();
        bus.run_until_quiescent(100).unwrap();
        assert_eq!(stats.in_flight.get(), 1);
        // a duplicate SubmitJob is dropped, and the t=40 arrival tick must
        // not admit the job a second time
        bus.post_to_dept(DeptId(0), Msg::SubmitJob { dept: DeptId(0), trace_idx: 0 })
            .unwrap();
        bus.set_now(40);
        bus.post_to_dept(DeptId(0), Msg::Tick { now: 40 }).unwrap();
        bus.run_until_quiescent(100).unwrap();
        assert_eq!(stats.in_flight.get(), 1, "job admitted twice");
        assert_eq!(stats.completed.get(), 0);
        // it completes exactly once (started at t=0, runtime 60)
        bus.set_now(100);
        bus.post_to_dept(DeptId(0), Msg::Tick { now: 100 }).unwrap();
        bus.run_until_quiescent(100).unwrap();
        assert_eq!(stats.completed.get(), 1);
        assert_eq!(stats.in_flight.get(), 0);
        // an out-of-range index is dropped, not a panic
        bus.post_to_dept(DeptId(0), Msg::SubmitJob { dept: DeptId(0), trace_idx: 99 })
            .unwrap();
        assert!(bus.run_until_quiescent(100).is_ok());
    }

    /// A roster whose batch trace arrives only over the frontend: every
    /// request must be ingested, acked with measurable latency, and
    /// completed, with the node ledger conserved.
    #[test]
    fn ingest_frontend_feeds_submit_jobs_and_collects_acks() {
        let mut cfg = ExperimentConfig::dynamic(64);
        cfg.ws_sample_period = 20;
        let horizon = 400;
        // submit times beyond the horizon: the tick arrival loop never
        // admits these — only the ingest path can
        let jobs: Vec<Job> = (0..10)
            .map(|i| Job { id: i + 1, submit: horizon + 1, size: 1, runtime: 40, requested: 120 })
            .collect();
        let rates = RateSeries { sample_period: 20, rates: vec![50.0; 100] };
        let depts = vec![
            ServeDept::batch("st", cfg.st_nodes, jobs),
            ServeDept::service("ws", cfg.ws_nodes, rates, reactive_scaler(64)),
        ];
        let reqs: Vec<crate::net::IngestRequest> = (0..10)
            .map(|i| crate::net::IngestRequest {
                dept: DeptId(0),
                trace_idx: i,
                due: i as u64 * 20,
            })
            .collect();
        let mut fe = crate::net::ServeFrontend::in_memory(reqs, 64, 0);
        let report = serve_roster_with_ingest(
            &cfg,
            &PolicyChoice::Base(PolicySpec::Cooperative),
            depts,
            horizon,
            0,
            Some(&mut fe),
        )
        .unwrap();
        assert_eq!(report.ingested, 10);
        assert_eq!(report.shed, 0);
        assert_eq!(report.ingest_bad, 0);
        assert_eq!(report.acked, 10, "every ingested job acks");
        assert!(report.grant_latency_p99_s >= report.grant_latency_mean_s);
        assert_eq!(report.completed, 10);
        assert_eq!(report.in_flight, 0);
        let held: u64 = report.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(report.free_end + held + report.down_end, report.cluster_nodes);
    }

    /// When arrivals outrun the bounded queue the overflow is shed and
    /// counted — never silently dropped — and what was admitted still
    /// flows to completion.
    #[test]
    fn ingest_backpressure_sheds_and_counts_overflow() {
        let mut cfg = ExperimentConfig::dynamic(64);
        cfg.ws_sample_period = 20;
        let horizon = 400;
        let jobs: Vec<Job> = (0..10)
            .map(|i| Job { id: i + 1, submit: horizon + 1, size: 1, runtime: 40, requested: 120 })
            .collect();
        let depts = vec![ServeDept::batch("st", cfg.st_nodes, jobs)];
        // all ten requests burst at t=0 against a cap-4 queue
        let reqs: Vec<crate::net::IngestRequest> = (0..10)
            .map(|i| crate::net::IngestRequest { dept: DeptId(0), trace_idx: i, due: 0 })
            .collect();
        let mut fe = crate::net::ServeFrontend::in_memory(reqs, 4, 2);
        let report = serve_roster_with_ingest(
            &cfg,
            &PolicyChoice::Base(PolicySpec::Cooperative),
            depts,
            horizon,
            0,
            Some(&mut fe),
        )
        .unwrap();
        assert_eq!(report.ingested, 4, "cap-4 queue admits four");
        assert_eq!(report.shed, 6, "overflow counted, not dropped");
        assert_eq!(report.acked, 4);
        assert_eq!(report.completed, 4);
        let held: u64 = report.per_dept.iter().map(|d| d.holding_end).sum();
        assert_eq!(report.free_end + held + report.down_end, report.cluster_nodes);
    }

    /// Requests for departments the roster cannot route are rejected and
    /// counted as bad input, without aborting the run.
    #[test]
    fn ingest_unroutable_departments_are_counted_not_fatal() {
        let mut cfg = ExperimentConfig::dynamic(64);
        cfg.ws_sample_period = 20;
        let jobs =
            vec![Job { id: 1, submit: 401, size: 1, runtime: 40, requested: 120 }];
        let depts = vec![ServeDept::batch("st", cfg.st_nodes, jobs)];
        let reqs = vec![
            crate::net::IngestRequest { dept: DeptId(0), trace_idx: 0, due: 0 },
            crate::net::IngestRequest { dept: DeptId(7), trace_idx: 0, due: 0 },
        ];
        let mut fe = crate::net::ServeFrontend::in_memory(reqs, 16, 0);
        let report = serve_roster_with_ingest(
            &cfg,
            &PolicyChoice::Base(PolicySpec::Cooperative),
            depts,
            400,
            0,
            Some(&mut fe),
        )
        .unwrap();
        assert_eq!(report.ingested, 2, "both decoded and queued");
        assert_eq!(report.ingest_bad, 1, "dept 7 never joined");
        assert_eq!(report.acked, 1);
        assert_eq!(report.completed, 1);
    }

    #[test]
    fn serve_config_builds_the_paper_pair_by_default() {
        let mut cfg = ExperimentConfig::dynamic(160);
        cfg.hpc.num_jobs = 60;
        cfg.hpc.horizon = 2000;
        cfg.web.horizon = 2000;
        let report = serve_config(&cfg, 2000, 0, |_, c| {
            let mut r = Reactive::new(c.total_nodes);
            Box::new(move |util, _| r.decide(util))
        })
        .unwrap();
        assert_eq!(report.per_dept.len(), 2);
        assert_eq!(report.per_dept[0].name, "st0");
        assert_eq!(report.per_dept[1].name, "ws0");
        assert_eq!(report.submitted, 60);
        assert_eq!(report.ws_shortage_node_secs, 0, "{report:?}");
    }
}
