//! Discrete-event simulation engine.
//!
//! The paper replays two-week traces at 100× wall-clock speedup; we go one
//! step further and simulate in virtual time (events jump the clock), which
//! is exact and runs the whole evaluation in seconds. The engine is a
//! classic event-heap design: `(time, seq, event)` ordered by time with a
//! monotonically increasing sequence number to make same-time ordering
//! deterministic (FIFO among equal timestamps).

mod engine;

pub use engine::{Engine, EventHandler, Schedule};

/// Simulation time in whole seconds since the trace epoch.
pub type SimTime = u64;
