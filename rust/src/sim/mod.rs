//! Discrete-event simulation engine.
//!
//! The paper's §III-D evaluation replays two-week traces at 100×
//! wall-clock speedup; we go one
//! step further and simulate in virtual time (events jump the clock), which
//! is exact and runs the whole evaluation in seconds. Events are `(time,
//! seq, event)` triples ordered by time with a monotonically increasing
//! sequence number making same-time ordering deterministic (FIFO among
//! equal timestamps).
//!
//! Two queue implementations sit behind the same [`Engine`] API:
//! * [`TimingWheel`] (default) — a bucketed calendar queue with an
//!   overflow heap for far-future events: O(1) amortized per event and
//!   allocation-free in steady state. This is the hot path for every
//!   figure, ablation, and sensitivity sweep.
//! * [`HeapQueue`] (via [`ReferenceEngine`]) — the classic binary heap,
//!   kept as the behavioral oracle; `tests/properties.rs` checks the two
//!   deliver bit-identical sequences over randomized schedules.

mod engine;
mod wheel;

pub use engine::{Engine, EventHandler, EventQueue, HeapQueue, ReferenceEngine, Schedule};
pub use wheel::TimingWheel;

/// Simulation time in whole seconds since the trace epoch.
pub type SimTime = u64;
