//! Discrete-event simulation engine.
//!
//! The paper's §III-D evaluation replays two-week traces at 100×
//! wall-clock speedup; we go one
//! step further and simulate in virtual time (events jump the clock), which
//! is exact and runs the whole evaluation in seconds. Events are `(time,
//! seq, event)` triples ordered by time with a monotonically increasing
//! sequence number making same-time ordering deterministic (FIFO among
//! equal timestamps).
//!
//! Four queue implementations sit behind the same [`Engine`] API — all
//! held bit-identical by the conformance suite in
//! `tests/engine_differential.rs`:
//! * [`TimingWheel`] (default) — a bucketed calendar queue with an
//!   overflow heap for far-future events: O(1) amortized per event and
//!   allocation-free in steady state.
//! * [`HierWheel`] — a two-level hierarchical wheel (4096×1 s cascading
//!   from 4096×~68 min, ~194-day span) so month-long horizons never touch
//!   the overflow `BinaryHeap`.
//! * [`LaneQueue`] — per-department event lanes (one [`HierWheel`] each)
//!   merged deterministically by `(time, seq)`; the storage layer behind
//!   `--engine sharded`.
//! * [`HeapQueue`] (via [`ReferenceEngine`]) — the classic binary heap,
//!   kept as the behavioral oracle.
//!
//! [`ShardedEngine`] runs a lane-decomposed model ([`ShardModel`])
//! concurrently within each timestamp via `std::thread::scope`, committing
//! effects in id order so results are bit-identical to the serial engine
//! at any worker count — see `sim/shard.rs`.

mod engine;
mod hier;
mod shard;
mod wheel;

pub use engine::{Engine, EventHandler, EventQueue, HeapQueue, ReferenceEngine, Schedule};
pub use hier::HierWheel;
pub use shard::{LaneEvent, LaneOut, LaneQueue, LaneRunner, ShardModel, ShardedEngine};
pub use wheel::TimingWheel;

/// Simulation time in whole seconds since the trace epoch.
pub type SimTime = u64;

/// Event-queue engine selection for experiment runs (`--engine`,
/// `[experiments] engine`). All variants are proven bit-identical by the
/// differential harness; they differ only in cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Binary-heap oracle — O(log n) per event.
    Reference,
    /// PR-1 one-level timing wheel (the default through PR 7; still
    /// selectable with `--engine wheel` and pinned bit-identical to
    /// `hier` by `prop_engine_default_hier_bit_identical_to_wheel`
    /// in properties.rs).
    Wheel,
    /// Two-level hierarchical wheel — far horizons stay heap-free.
    /// The default since PR 8: same outputs as `wheel` (differentially
    /// proven), lower cost on long-horizon runs.
    #[default]
    Hier,
    /// Per-department lane queues with a deterministic `(time, seq)`
    /// merge (lane-partitioned storage; the coordinator's handler stays
    /// serial — see ARCHITECTURE.md).
    Sharded,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "reference" | "heap" => Ok(Self::Reference),
            "wheel" => Ok(Self::Wheel),
            "hier" | "hierarchical" => Ok(Self::Hier),
            "sharded" | "lanes" => Ok(Self::Sharded),
            other => Err(format!(
                "unknown engine '{other}' (expected reference|wheel|hier|sharded)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Reference => "reference",
            Self::Wheel => "wheel",
            Self::Hier => "hier",
            Self::Sharded => "sharded",
        }
    }
}

#[cfg(test)]
mod kind_tests {
    use super::EngineKind;

    #[test]
    fn engine_kind_parses_and_round_trips() {
        for kind in [
            EngineKind::Reference,
            EngineKind::Wheel,
            EngineKind::Hier,
            EngineKind::Sharded,
        ] {
            assert_eq!(EngineKind::parse(kind.name()), Ok(kind));
        }
        assert_eq!(EngineKind::parse("heap"), Ok(EngineKind::Reference));
        assert_eq!(EngineKind::parse("hierarchical"), Ok(EngineKind::Hier));
        assert!(EngineKind::parse("quantum").is_err());
        assert_eq!(EngineKind::default(), EngineKind::Hier, "hier is the default since PR 8");
    }
}
