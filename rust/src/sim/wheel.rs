//! Bucketed calendar queue (a one-level timing wheel with an overflow
//! heap) — the default event queue behind [`crate::sim::Engine`].
//!
//! Layout: a window of [`WHEEL_SLOTS`] one-second slots starting at
//! `start`; slot `i` holds exactly the events at time `start + i`, in
//! insertion order. Because the engine assigns strictly increasing `seq`
//! numbers and every path below appends in `seq` order, a slot's insertion
//! order *is* `(time, seq)` order — same-timestamp delivery stays FIFO
//! bit-for-bit with the reference heap (`tests/properties.rs` proves the
//! equivalence over randomized schedules).
//!
//! Events beyond the window land in an overflow `BinaryHeap`; when the
//! window drains, the wheel jumps straight to the earliest overflow time
//! and migrates everything that now fits (heap pop order is `(time, seq)`,
//! so migrated events append in order ahead of any later direct pushes —
//! their seqs are necessarily smaller). An idle jump can leave `start`
//! ahead of the engine clock; events pushed into that gap afterwards are
//! routed back through the overflow heap ("stragglers") and delivered
//! before anything in the window — they are strictly earlier than `start`.
//!
//! Cost model: O(1) push/pop amortized, no allocation in steady state
//! (slot vectors and the active batch recycle their capacity), one bitmap
//! word-scan per empty region instead of per-event heap rebalancing, and
//! same-timestamp storms drain as one batch.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::engine::{Entry, EventQueue};
use super::SimTime;

/// One-second slots per window. 4096 s (~68 min) covers the WS sampling
/// cadence and most inter-event gaps of the two-week traces; anything
/// farther takes one extra trip through the overflow heap.
const WHEEL_SLOTS: usize = 4096;
const WORDS: usize = WHEEL_SLOTS / 64;

/// The timing wheel. See the module docs for the invariants.
pub struct TimingWheel<E> {
    /// `slots[i]` holds the events at time `start + i`, in seq order.
    slots: Vec<Vec<E>>,
    /// Occupancy bitmap over `slots` (bit i set ⇔ slot i non-empty).
    bits: [u64; WORDS],
    /// Simulation time of slot 0.
    start: SimTime,
    /// Next slot index to inspect; only ever moves forward except when a
    /// push lands behind it (the skipped slots are provably empty).
    cursor: usize,
    /// Batch being drained, reversed so `pop` takes from the back in FIFO
    /// order without shifting.
    active: Vec<E>,
    active_time: SimTime,
    /// Far-future events and post-jump stragglers, in `(time, seq)` order.
    overflow: BinaryHeap<Reverse<Entry<E>>>,
    len: usize,
}

impl<E> Default for TimingWheel<E> {
    fn default() -> Self {
        Self {
            slots: std::iter::repeat_with(Vec::new).take(WHEEL_SLOTS).collect(),
            bits: [0; WORDS],
            start: 0,
            cursor: 0,
            active: Vec::new(),
            active_time: 0,
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }
}

impl<E> TimingWheel<E> {
    #[inline]
    fn set_bit(&mut self, i: usize) {
        self.bits[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    fn clear_bit(&mut self, i: usize) {
        self.bits[i / 64] &= !(1 << (i % 64));
    }

    /// First occupied slot index at or after `from`, via the bitmap.
    fn scan_from(&self, from: usize) -> Option<usize> {
        if from >= WHEEL_SLOTS {
            return None;
        }
        let mut w = from / 64;
        let mut word = self.bits[w] & (!0u64 << (from % 64));
        loop {
            if word != 0 {
                return Some(w * 64 + word.trailing_zeros() as usize);
            }
            w += 1;
            if w == WORDS {
                return None;
            }
            word = self.bits[w];
        }
    }
}

impl<E> EventQueue<E> for TimingWheel<E> {
    fn push(&mut self, time: SimTime, seq: u64, ev: E) {
        self.len += 1;
        if time < self.start {
            // the window already jumped past `time` (idle jump between
            // runs); deliver through the overflow heap, which next_time
            // checks before the window
            self.overflow.push(Reverse(Entry { time, seq, ev }));
            return;
        }
        let offset = time - self.start;
        if offset >= WHEEL_SLOTS as u64 {
            self.overflow.push(Reverse(Entry { time, seq, ev }));
            return;
        }
        let idx = offset as usize;
        self.slots[idx].push(ev);
        self.set_bit(idx);
        if idx < self.cursor {
            // every slot in [idx, cursor) was scanned empty — rewinding
            // only re-scans empties, it cannot reorder
            self.cursor = idx;
        }
    }

    fn next_time(&mut self) -> Option<SimTime> {
        loop {
            if !self.active.is_empty() {
                return Some(self.active_time);
            }
            // stragglers are strictly earlier than everything in the window
            if let Some(Reverse(e)) = self.overflow.peek() {
                if e.time < self.start {
                    return Some(e.time);
                }
            }
            if let Some(idx) = self.scan_from(self.cursor) {
                self.cursor = idx;
                return Some(self.start + idx as u64);
            }
            // window exhausted: jump to the earliest overflow event and
            // migrate everything that now fits
            let head_time = match self.overflow.peek() {
                Some(Reverse(e)) => e.time,
                None => return None,
            };
            self.start = head_time;
            self.cursor = 0;
            while let Some(Reverse(e)) = self.overflow.peek() {
                // heap pops ascending from the new `start`, so the offset
                // cannot underflow; comparing offsets (not `start + W`)
                // also keeps times near `SimTime::MAX` deliverable
                if e.time - self.start >= WHEEL_SLOTS as u64 {
                    break;
                }
                // phoenix-lint: allow(panic_path): peeked non-empty just above; pop cannot fail
                let Reverse(e) = self.overflow.pop().unwrap();
                let idx = (e.time - self.start) as usize;
                self.slots[idx].push(e.ev);
                self.set_bit(idx);
            }
            // loop: the scan now finds slot 0 (non-empty by construction)
        }
    }

    fn pop(&mut self) -> Option<(SimTime, E)> {
        loop {
            if let Some(ev) = self.active.pop() {
                self.len -= 1;
                return Some((self.active_time, ev));
            }
            let t = self.next_time()?;
            if let Some(Reverse(e)) = self.overflow.peek() {
                if e.time < self.start {
                    // phoenix-lint: allow(panic_path): guarded by the peek on the line above
                    let Reverse(e) = self.overflow.pop().unwrap();
                    self.len -= 1;
                    return Some((e.time, e.ev));
                }
            }
            // cursor sits on the non-empty slot for `t`: swap the whole
            // slot into the active batch (batch-drain; the swap hands the
            // slot the batch's old empty-but-allocated vector back)
            std::mem::swap(&mut self.slots[self.cursor], &mut self.active);
            self.active.reverse();
            self.active_time = t;
            self.clear_bit(self.cursor);
        }
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(w: &mut TimingWheel<&'static str>) -> Vec<(SimTime, &'static str)> {
        let mut out = Vec::new();
        while let Some(x) = w.pop() {
            out.push(x);
        }
        out
    }

    #[test]
    fn orders_within_window_and_fifo_on_ties() {
        let mut w = TimingWheel::default();
        w.push(20, 1, "a");
        w.push(10, 2, "b");
        w.push(10, 3, "c");
        w.push(0, 4, "d");
        assert_eq!(w.len(), 4);
        assert_eq!(drain(&mut w), vec![(0, "d"), (10, "b"), (10, "c"), (20, "a")]);
        assert!(w.is_empty());
    }

    #[test]
    fn far_future_overflows_and_migrates() {
        let mut w = TimingWheel::default();
        w.push(10, 1, "near");
        w.push(1_000_000, 2, "far");
        assert_eq!(w.pop(), Some((10, "near")));
        // still beyond the original window: overflow again
        w.push(500_000, 3, "mid");
        assert_eq!(w.pop(), Some((500_000, "mid")));
        assert_eq!(w.pop(), Some((1_000_000, "far")));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn overflow_and_direct_pushes_interleave_fifo_on_equal_times() {
        let mut w = TimingWheel::default();
        w.push(5000, 1, "first"); // overflow (window is [0, 4096))
        assert_eq!(w.next_time(), Some(5000)); // jump + migrate
        w.push(5000, 2, "second"); // direct push into the migrated slot
        assert_eq!(drain(&mut w), vec![(5000, "first"), (5000, "second")]);
    }

    #[test]
    fn straggler_behind_a_jumped_window_is_delivered_first() {
        let mut w = TimingWheel::default();
        w.push(1_000_000, 1, "far");
        assert_eq!(w.next_time(), Some(1_000_000)); // window jumped
        w.push(5, 2, "late");
        w.push(7, 3, "later");
        assert_eq!(
            drain(&mut w),
            vec![(5, "late"), (7, "later"), (1_000_000, "far")]
        );
    }

    #[test]
    fn push_behind_cursor_rewinds() {
        let mut w = TimingWheel::default();
        w.push(100, 1, "b");
        assert_eq!(w.next_time(), Some(100)); // cursor advanced to 100
        w.push(40, 2, "a");
        assert_eq!(drain(&mut w), vec![(40, "a"), (100, "b")]);
    }

    #[test]
    fn same_time_push_during_batch_drain_runs_after_batch() {
        let mut w = TimingWheel::default();
        w.push(10, 1, "a");
        w.push(10, 2, "b");
        assert_eq!(w.pop(), Some((10, "a"))); // batch active
        w.push(10, 3, "c"); // same timestamp, mid-drain
        assert_eq!(w.pop(), Some((10, "b")));
        assert_eq!(w.pop(), Some((10, "c")));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn window_boundary_is_exact() {
        let mut w = TimingWheel::default();
        w.push(WHEEL_SLOTS as u64 - 1, 1, "in"); // last slot of the window
        w.push(WHEEL_SLOTS as u64, 2, "out"); // first overflow time
        assert_eq!(
            drain(&mut w),
            vec![(WHEEL_SLOTS as u64 - 1, "in"), (WHEEL_SLOTS as u64, "out")]
        );
    }

    #[test]
    fn delivers_events_at_time_max() {
        // regression: the window jump must not strand events whose slot
        // offset computation would saturate at SimTime::MAX
        let mut w = TimingWheel::default();
        w.push(10, 1, "near");
        w.push(u64::MAX, 2, "end-of-time");
        assert_eq!(w.pop(), Some((10, "near")));
        assert_eq!(w.pop(), Some((u64::MAX, "end-of-time")));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn len_tracks_across_all_paths() {
        let mut w = TimingWheel::default();
        w.push(1, 1, "a");
        w.push(100_000, 2, "b");
        assert_eq!(w.len(), 2);
        w.pop();
        assert_eq!(w.len(), 1);
        w.next_time(); // jump
        w.push(50, 3, "straggler");
        assert_eq!(w.len(), 2);
        drain(&mut w);
        assert_eq!(w.len(), 0);
    }
}
