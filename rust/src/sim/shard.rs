//! Sharded per-department event lanes: lane-partitioned event storage
//! ([`LaneQueue`]) and a scoped-thread engine ([`ShardedEngine`]) that
//! drains lanes concurrently within a timestamp while staying bit-for-bit
//! identical to the serial engine.
//!
//! # Lanes
//!
//! Events carry a lane address through [`LaneEvent`]: `Some(d)` for
//! department-local events, `None` for cluster-wide (global) events.
//! [`LaneQueue`] keeps each lane in its own [`HierWheel`] and pops by a
//! deterministic id-ordered merge — the minimum `(time, seq)` across lane
//! heads, which is exactly the global schedule order (seqs are unique), so
//! it is a drop-in [`EventQueue`] for the serial [`Engine`](super::Engine).
//!
//! # The lane contract
//!
//! [`ShardedEngine`] runs a [`ShardModel`], which splits event handling in
//! two phases the type system holds apart:
//!
//! - **lane phase** — [`ShardModel::on_lane`] gets `&self` (shared state
//!   read-only in aggregate, but by contract untouched), `&mut` its own
//!   lane, and a [`LaneOut`] to emit follow-up events and effects. Within
//!   one timestamp, maximal seq-contiguous runs of lane events execute
//!   concurrently via `std::thread::scope`, partitioned by lane.
//! - **commit phase** — the collected outputs are sorted by `seq` (the
//!   id-ordered merge) and [`ShardModel::commit`] applies effects to the
//!   shared state serially, in exactly the order the serial engine would
//!   have produced them. Cross-lane writes travel as zero-delay follow-up
//!   events, never as direct mutation.
//!
//! Global events ([`LaneEvent::lane`] → `None`, e.g. a lease tick, a node
//! crash, a department join) are serial barriers with full access to the
//! lanes vector — a join may grow it mid-run.
//!
//! Because `on_lane` can only read the model and write its own lane, the
//! outcome is independent of worker count and interleaving; the
//! differential harness (`tests/engine_differential.rs`) checks the
//! engine against the serial [`LaneRunner`] adapter over randomized
//! adversarial programs at several worker layouts.
//!
//! The consolidation coordinator's handlers couple through the shared RPS
//! ledger *within* a timestamp (grants observed by later same-tick
//! events), so it keeps the serial handler and uses [`LaneQueue`] for
//! lane-partitioned storage only (`--engine sharded`); see
//! ARCHITECTURE.md "Engine hierarchy & determinism proof".

use super::engine::{EventHandler, EventQueue, Schedule};
use super::hier::HierWheel;
use super::SimTime;

/// Lane addressing for shardable event types.
pub trait LaneEvent {
    /// The department lane this event belongs to, or `None` for global
    /// (cluster-wide) events that act as serial barriers.
    fn lane(&self) -> Option<usize>;
}

/// Per-lane event storage with a deterministic id-ordered merge.
///
/// Lane index 0 holds global events; department `d` maps to lane `d + 1`.
/// Lanes are created on first use. Pop order is the minimum `(time, seq)`
/// across lane heads — bit-identical to a single queue.
pub struct LaneQueue<E> {
    lanes: Vec<HierWheel<(u64, E)>>,
    len: usize,
}

impl<E> Default for LaneQueue<E> {
    fn default() -> Self {
        Self { lanes: Vec::new(), len: 0 }
    }
}

impl<E> LaneQueue<E> {
    /// Number of lanes materialized so far (including the global lane).
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Lane index holding the head `(time, seq)`, with that key.
    fn best(&mut self) -> Option<(usize, SimTime, u64)> {
        let mut best: Option<(usize, SimTime, u64)> = None;
        for li in 0..self.lanes.len() {
            if let Some((t, &(seq, _))) = self.lanes[li].peek() {
                let better = match best {
                    None => true,
                    Some((_, bt, bs)) => (t, seq) < (bt, bs),
                };
                if better {
                    best = Some((li, t, seq));
                }
            }
        }
        best
    }

    /// Head event's `(time, seq, lane)` without removing it; the lane is
    /// `None` for a global event.
    pub fn peek_meta(&mut self) -> Option<(SimTime, u64, Option<usize>)> {
        self.best()
            .map(|(li, t, seq)| (t, seq, if li == 0 { None } else { Some(li - 1) }))
    }

    /// Pop the head in `(time, seq)` order, keeping the seq.
    pub fn pop_entry(&mut self) -> Option<(SimTime, u64, E)> {
        let (li, _, _) = self.best()?;
        // phoenix-lint: allow(panic_path): best() just located a non-empty lane
        let (t, (seq, ev)) = self.lanes[li].pop().expect("peeked head vanished");
        self.len -= 1;
        Some((t, seq, ev))
    }
}

impl<E: LaneEvent> EventQueue<E> for LaneQueue<E> {
    fn push(&mut self, time: SimTime, seq: u64, ev: E) {
        let li = ev.lane().map_or(0, |d| d + 1);
        if li >= self.lanes.len() {
            self.lanes.resize_with(li + 1, HierWheel::default);
        }
        // the payload carries the seq so the cross-lane merge can compare
        // equal-timestamp heads
        self.lanes[li].push(time, seq, (seq, ev));
        self.len += 1;
    }

    fn next_time(&mut self) -> Option<SimTime> {
        self.best().map(|(_, t, _)| t)
    }

    fn pop(&mut self) -> Option<(SimTime, E)> {
        self.pop_entry().map(|(t, _, ev)| (t, ev))
    }

    fn len(&self) -> usize {
        self.len
    }
}

/// Output handle for the lane phase: follow-up events plus effects for the
/// serial commit phase. Mirrors [`Schedule`]'s clamping semantics.
pub struct LaneOut<E, F> {
    now: SimTime,
    follow_ups: Vec<(SimTime, E)>,
    effects: Vec<F>,
}

impl<E, F> LaneOut<E, F> {
    fn new(now: SimTime) -> Self {
        Self { now, follow_ups: Vec::new(), effects: Vec::new() }
    }

    /// Current simulation time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule a follow-up event at absolute time `at` (clamped to now).
    pub fn at(&mut self, at: SimTime, ev: E) {
        self.follow_ups.push((at.max(self.now), ev));
    }

    /// Schedule a follow-up event after `delay` seconds.
    pub fn after(&mut self, delay: u64, ev: E) {
        self.follow_ups.push((self.now + delay, ev));
    }

    /// Emit an effect for the serial commit phase.
    pub fn effect(&mut self, f: F) {
        self.effects.push(f);
    }
}

/// A simulation model decomposed for lane-parallel execution. See the
/// module docs for the contract each method must uphold.
pub trait ShardModel: Sync {
    type Ev: LaneEvent + Send;
    type Lane: Send;
    type Effect: Send;

    /// Lane phase: handle a lane-addressed event. Runs concurrently across
    /// lanes within a timestamp — must touch only `lane`'s state.
    fn on_lane(
        &self,
        lane: &mut Self::Lane,
        ev: Self::Ev,
        now: SimTime,
        out: &mut LaneOut<Self::Ev, Self::Effect>,
    );

    /// Commit phase: apply one effect to the shared state. Serial, in
    /// global `(time, seq)` order — must not touch lane state (cross-lane
    /// writes go through zero-delay follow-up events).
    fn commit(&mut self, lane: usize, effect: Self::Effect, now: SimTime, sched: &mut Schedule<Self::Ev>);

    /// Global events: a serial barrier with full access (a department
    /// join may push a new lane).
    fn on_global(
        &mut self,
        lanes: &mut Vec<Self::Lane>,
        ev: Self::Ev,
        now: SimTime,
        sched: &mut Schedule<Self::Ev>,
    );
}

/// Serial adapter: runs a [`ShardModel`] on any queue-backed
/// [`Engine`](super::Engine) by executing lane phase + commit per event,
/// in delivery order. This is the oracle the sharded engine is held
/// bit-identical to.
pub struct LaneRunner<M: ShardModel> {
    pub model: M,
    pub lanes: Vec<M::Lane>,
}

impl<M: ShardModel> LaneRunner<M> {
    pub fn new(model: M, lanes: Vec<M::Lane>) -> Self {
        Self { model, lanes }
    }
}

impl<M: ShardModel> EventHandler<M::Ev> for LaneRunner<M> {
    fn handle(&mut self, ev: M::Ev, sched: &mut Schedule<M::Ev>) {
        let now = sched.now();
        match ev.lane() {
            None => self.model.on_global(&mut self.lanes, ev, now, sched),
            Some(l) => {
                assert!(l < self.lanes.len(), "event addressed to unknown lane {l}");
                let mut out = LaneOut::new(now);
                self.model.on_lane(&mut self.lanes[l], ev, now, &mut out);
                // follow-ups first, then commit follow-ups — the sharded
                // engine assigns seqs in the same order
                for (at, e) in out.follow_ups {
                    sched.at(at, e);
                }
                for eff in out.effects {
                    self.model.commit(l, eff, now, sched);
                }
            }
        }
    }
}

/// The lane-parallel engine: one *run* uses multiple cores while the
/// observable behavior stays bit-identical to the serial engine for any
/// worker count (including 1). See the module docs for the phase rules.
pub struct ShardedEngine<M: ShardModel> {
    model: M,
    lanes: Vec<M::Lane>,
    queue: LaneQueue<M::Ev>,
    now: SimTime,
    seq: u64,
    processed: u64,
    workers: usize,
    scratch: Vec<(SimTime, M::Ev)>,
}

impl<M: ShardModel> ShardedEngine<M> {
    /// `workers = 0` resolves to the core count; `1` is the serial
    /// fallback (identical results either way).
    pub fn new(model: M, lanes: Vec<M::Lane>, workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        Self {
            model,
            lanes,
            queue: LaneQueue::default(),
            now: 0,
            seq: 0,
            processed: 0,
            workers,
            scratch: Vec::new(),
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    pub fn lanes(&self) -> &[M::Lane] {
        &self.lanes
    }

    /// Tear down into the final model + lane states (for comparisons).
    pub fn into_parts(self) -> (M, Vec<M::Lane>) {
        (self.model, self.lanes)
    }

    /// Seed an event (past times clamp to now, as in `Engine::schedule`).
    pub fn schedule(&mut self, at: SimTime, ev: M::Ev) {
        self.seq += 1;
        self.queue.push(at.max(self.now), self.seq, ev);
    }

    fn push(&mut self, at: SimTime, ev: M::Ev) {
        self.seq += 1;
        self.queue.push(at.max(self.now), self.seq, ev);
    }

    /// Run the lane phase for one seq-contiguous group of lane events at
    /// the current timestamp; returns outputs sorted back into seq order.
    #[allow(clippy::type_complexity)]
    fn lane_phase(
        &mut self,
        group: Vec<(u64, usize, M::Ev)>,
    ) -> Vec<(u64, usize, LaneOut<M::Ev, M::Effect>)> {
        let now = self.now;
        // partition by lane, preserving per-lane seq order
        let mut by_lane: std::collections::BTreeMap<usize, Vec<(u64, M::Ev)>> =
            std::collections::BTreeMap::new();
        for (seq, lane, ev) in group {
            assert!(lane < self.lanes.len(), "event addressed to unknown lane {lane}");
            by_lane.entry(lane).or_default().push((seq, ev));
        }
        let mut tasks: Vec<(usize, &mut M::Lane, Vec<(u64, M::Ev)>)> = Vec::new();
        for (li, lane_state) in self.lanes.iter_mut().enumerate() {
            if let Some(evs) = by_lane.remove(&li) {
                tasks.push((li, lane_state, evs));
            }
        }
        let model = &self.model;
        let run_bucket = |bucket: Vec<(usize, &mut M::Lane, Vec<(u64, M::Ev)>)>| {
            let mut part = Vec::new();
            for (li, lane, evs) in bucket {
                for (seq, ev) in evs {
                    let mut out = LaneOut::new(now);
                    model.on_lane(lane, ev, now, &mut out);
                    part.push((li, seq, out));
                }
            }
            part
        };
        let workers = self.workers.min(tasks.len());
        let mut outs: Vec<(u64, usize, LaneOut<M::Ev, M::Effect>)> = Vec::new();
        if workers <= 1 {
            for (li, seq, out) in run_bucket(tasks) {
                outs.push((seq, li, out));
            }
        } else {
            let mut buckets: Vec<Vec<(usize, &mut M::Lane, Vec<(u64, M::Ev)>)>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (i, task) in tasks.into_iter().enumerate() {
                buckets[i % workers].push(task);
            }
            let parts: Vec<_> = std::thread::scope(|s| {
                let handles: Vec<_> = buckets
                    .into_iter()
                    .map(|bucket| s.spawn(|| run_bucket(bucket)))
                    .collect();
                handles
                    .into_iter()
                    // phoenix-lint: allow(panic_path): join() only errs if a worker panicked — propagate
                    .map(|h| h.join().expect("lane worker panicked"))
                    .collect()
            });
            for part in parts {
                for (li, seq, out) in part {
                    outs.push((seq, li, out));
                }
            }
        }
        // the deterministic id-ordered merge: commit in global seq order
        outs.sort_unstable_by_key(|&(seq, _, _)| seq);
        outs
    }

    /// Run until the queue drains or the clock passes `horizon` (same
    /// landing rule as `Engine::run_until`).
    pub fn run_until(&mut self, horizon: SimTime) {
        while let Some(t) = self.queue.next_time() {
            if t > horizon {
                break;
            }
            self.now = t;
            // gather the maximal seq-contiguous run of lane events at t
            let mut group: Vec<(u64, usize, M::Ev)> = Vec::new();
            loop {
                match self.queue.peek_meta() {
                    Some((tt, _, Some(_))) if tt == t => {
                        // phoenix-lint: allow(panic_path): peek_meta() just returned Some at this time
                        let (_, seq, ev) = self.queue.pop_entry().expect("peeked head vanished");
                        // phoenix-lint: allow(panic_path): peek_meta() reported Some(lane) for this head
                        let lane = ev.lane().expect("peek said lane event");
                        group.push((seq, lane, ev));
                    }
                    _ => break,
                }
            }
            if group.is_empty() {
                // head is a global event at t: a serial barrier
                // phoenix-lint: allow(panic_path): next_time() returned Some, so the queue is non-empty
                let (_, _, ev) = self.queue.pop_entry().expect("next_time reported an event");
                self.processed += 1;
                let mut sched = Schedule::new(t, std::mem::take(&mut self.scratch));
                self.model.on_global(&mut self.lanes, ev, t, &mut sched);
                let mut pending = sched.into_pending();
                for (at, follow) in pending.drain(..) {
                    self.push(at, follow);
                }
                self.scratch = pending;
                continue;
            }
            self.processed += group.len() as u64;
            let outs = self.lane_phase(group);
            for (_, lane, out) in outs {
                // per event: lane follow-ups first, then commit follow-ups
                // — the same seq assignment order as the serial adapter
                for (at, follow) in out.follow_ups {
                    self.push(at, follow);
                }
                let mut sched = Schedule::new(t, std::mem::take(&mut self.scratch));
                for eff in out.effects {
                    self.model.commit(lane, eff, t, &mut sched);
                }
                let mut pending = sched.into_pending();
                for (at, follow) in pending.drain(..) {
                    self.push(at, follow);
                }
                self.scratch = pending;
            }
            // zero-delay follow-ups at t form later seq-contiguous groups;
            // the outer loop re-polls and picks them up at the same time
        }
        if horizon != SimTime::MAX && self.now < horizon {
            self.now = horizon;
        }
    }

    /// Drain everything (no horizon).
    pub fn run(&mut self) {
        self.run_until(SimTime::MAX);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Engine, ReferenceEngine};
    use super::*;

    /// Toy shard model: department lanes record work and claim nodes from
    /// a shared ledger; grants travel back as zero-delay lane events.
    #[derive(Clone, Debug, PartialEq)]
    enum TEv {
        Work { dept: u16, id: u32 },
        Claim { dept: u16, nodes: u64 },
        Grant { dept: u16, nodes: u64 },
        Tick,
        Join,
    }

    impl LaneEvent for TEv {
        fn lane(&self) -> Option<usize> {
            match self {
                TEv::Work { dept, .. } | TEv::Claim { dept, .. } | TEv::Grant { dept, .. } => {
                    Some(*dept as usize)
                }
                TEv::Tick | TEv::Join => None,
            }
        }
    }

    #[derive(Clone, Debug, Default, PartialEq)]
    struct TLane {
        seen: Vec<(SimTime, u32)>,
        held: u64,
    }

    enum TEff {
        Claim(u64),
    }

    #[derive(Clone, Debug, PartialEq)]
    struct TModel {
        free: u64,
        ticks: u32,
        commits: Vec<(SimTime, usize, u64)>,
    }

    impl ShardModel for TModel {
        type Ev = TEv;
        type Lane = TLane;
        type Effect = TEff;

        fn on_lane(&self, lane: &mut TLane, ev: TEv, now: SimTime, out: &mut LaneOut<TEv, TEff>) {
            match ev {
                TEv::Work { dept, id } => {
                    lane.seen.push((now, id));
                    if id < 3 {
                        // chained same-lane follow-up
                        out.after(7, TEv::Work { dept, id: id + 1 });
                    }
                }
                TEv::Claim { nodes, .. } => out.effect(TEff::Claim(nodes)),
                TEv::Grant { nodes, .. } => lane.held += nodes,
            }
        }

        fn commit(&mut self, lane: usize, eff: TEff, now: SimTime, sched: &mut Schedule<TEv>) {
            let TEff::Claim(want) = eff;
            let got = want.min(self.free);
            self.free -= got;
            self.commits.push((now, lane, got));
            if got > 0 {
                // zero-delay cross-back into the lane
                sched.at(now, TEv::Grant { dept: lane as u16, nodes: got });
            }
        }

        fn on_global(
            &mut self,
            lanes: &mut Vec<TLane>,
            ev: TEv,
            _now: SimTime,
            _sched: &mut Schedule<TEv>,
        ) {
            match ev {
                TEv::Tick => {
                    self.ticks += 1;
                    self.free += 2;
                }
                TEv::Join => lanes.push(TLane::default()),
                _ => unreachable!("lane event reached on_global"),
            }
        }
    }

    fn model() -> TModel {
        TModel { free: 5, ticks: 0, commits: Vec::new() }
    }

    /// A program with same-timestamp storms across lanes, contended
    /// claims, a mid-run join, and global barriers.
    fn seed(mut sched: impl FnMut(SimTime, TEv)) {
        for d in 0..3u16 {
            sched(10, TEv::Work { dept: d, id: 0 });
            sched(10, TEv::Claim { dept: d, nodes: 2 });
        }
        sched(10, TEv::Tick);
        for d in 0..3u16 {
            sched(10, TEv::Work { dept: d, id: 100 + d as u32 });
        }
        sched(20, TEv::Join);
        sched(20, TEv::Work { dept: 3, id: 7 });
        sched(25, TEv::Claim { dept: 3, nodes: 9 });
        sched(30, TEv::Tick);
    }

    fn run_sharded(workers: usize) -> (TModel, Vec<TLane>, SimTime, u64) {
        let mut eng = ShardedEngine::new(model(), vec![TLane::default(); 3], workers);
        seed(|t, ev| eng.schedule(t, ev));
        eng.run_until(1_000);
        let (now, processed) = (eng.now(), eng.processed());
        let (m, lanes) = eng.into_parts();
        (m, lanes, now, processed)
    }

    fn run_serial<Q: EventQueue<TEv>>(queue: Q) -> (TModel, Vec<TLane>, SimTime, u64) {
        let mut eng = Engine::with_queue(queue);
        seed(|t, ev| eng.schedule(t, ev));
        let mut runner = LaneRunner::new(model(), vec![TLane::default(); 3]);
        eng.run_until(&mut runner, 1_000);
        (runner.model, runner.lanes, eng.now(), eng.processed())
    }

    #[test]
    fn sharded_matches_serial_oracle_across_worker_layouts() {
        let oracle = {
            let mut eng: ReferenceEngine<TEv> = Engine::new_reference();
            seed(|t, ev| eng.schedule(t, ev));
            let mut runner = LaneRunner::new(model(), vec![TLane::default(); 3]);
            eng.run_until(&mut runner, 1_000);
            (runner.model, runner.lanes, eng.now(), eng.processed())
        };
        for workers in [1, 2, 0] {
            assert_eq!(run_sharded(workers), oracle, "workers={workers}");
        }
    }

    #[test]
    fn lane_queue_is_a_drop_in_queue_for_the_serial_engine() {
        let heap = run_serial(super::super::HeapQueue::default());
        let lanes = run_serial(LaneQueue::default());
        assert_eq!(lanes, heap);
    }

    #[test]
    fn contended_claims_commit_in_schedule_order() {
        // free = 5; three claims of 2 at t=10 in dept order: grants 2, 2, 1
        let (m, lanes, _, _) = run_sharded(2);
        let t10: Vec<u64> =
            m.commits.iter().filter(|(t, _, _)| *t == 10).map(|(_, _, g)| *g).collect();
        assert_eq!(t10, vec![2, 2, 1]);
        assert_eq!(lanes[0].held, 2);
        assert_eq!(lanes[1].held, 2);
        assert_eq!(lanes[2].held, 1);
        // the join at t=20 added lane 3; its claim at 25 drew on the
        // tick's replenishment (free was 5-5+2 = 2)
        assert_eq!(lanes[3].held, 2);
        assert_eq!(m.free, 2); // +2 from the final tick at t=30
        assert_eq!(m.ticks, 2);
    }

    #[test]
    fn chained_lane_followups_keep_fifo() {
        let (_, lanes, _, _) = run_sharded(0);
        // dept 0: Work id 0 at 10 chains 1@17, 2@24, 3@31; storm id 100@10
        assert_eq!(lanes[0].seen, vec![(10, 0), (10, 100), (17, 1), (24, 2), (31, 3)]);
    }

    #[test]
    fn lane_queue_reports_len_and_lanes() {
        let mut q: LaneQueue<TEv> = LaneQueue::default();
        q.push(5, 1, TEv::Tick);
        q.push(3, 2, TEv::Work { dept: 1, id: 9 });
        assert_eq!(q.len(), 2);
        assert_eq!(q.lane_count(), 3); // global + depts 0..=1
        assert_eq!(q.next_time(), Some(3));
        assert_eq!(q.pop(), Some((3, TEv::Work { dept: 1, id: 9 })));
        assert_eq!(q.pop(), Some((5, TEv::Tick)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }
}
