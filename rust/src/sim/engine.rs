//! The event queue abstraction and dispatch loop.
//!
//! The engine is generic over its queue: the default is the zero-allocation
//! [`TimingWheel`] (see `sim/wheel.rs`); [`HeapQueue`] is the classic
//! `BinaryHeap` kept as the reference implementation — the equivalence
//! property test in `tests/properties.rs` holds the two to bit-identical
//! `(time, seq)` delivery order.
//!
//! Dispatch reuses one per-engine scratch buffer for handler follow-ups
//! (the `Schedule` handle), so the steady-state hot loop performs no heap
//! allocation per event.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::wheel::TimingWheel;
use super::SimTime;

/// Handle used by handlers to schedule further events.
pub struct Schedule<E> {
    now: SimTime,
    pending: Vec<(SimTime, E)>,
}

impl<E> Schedule<E> {
    /// Build a handle over an existing pending buffer (the engines thread
    /// one scratch buffer through every dispatch to avoid allocation).
    pub(crate) fn new(now: SimTime, pending: Vec<(SimTime, E)>) -> Self {
        Self { now, pending }
    }

    /// Hand the pending buffer back to the engine that owns it.
    pub(crate) fn into_pending(self) -> Vec<(SimTime, E)> {
        self.pending
    }

    /// Current simulation time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (clamped to now — events may not
    /// be scheduled in the past).
    pub fn at(&mut self, at: SimTime, ev: E) {
        self.pending.push((at.max(self.now), ev));
    }

    /// Schedule `ev` after `delay` seconds.
    pub fn after(&mut self, delay: u64, ev: E) {
        self.pending.push((self.now + delay, ev));
    }
}

/// Implemented by the simulation model; the engine is generic over the
/// event type so each experiment defines its own compact enum.
pub trait EventHandler<E> {
    /// Process one event; schedule follow-ups through `sched`.
    fn handle(&mut self, ev: E, sched: &mut Schedule<E>);
}

/// A pending event: ordered by `(time, seq)` so equal-timestamp delivery
/// is FIFO in schedule order.
pub(crate) struct Entry<E> {
    pub(crate) time: SimTime,
    pub(crate) seq: u64,
    pub(crate) ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Priority queue of `(time, seq, event)` triples delivering in ascending
/// `(time, seq)` order. `seq` is assigned by the engine in schedule order,
/// which makes equal-timestamp delivery FIFO — every implementation must
/// preserve that order exactly (the determinism contract the figure sweeps
/// and the property tests rely on).
pub trait EventQueue<E> {
    /// Insert an event. `time` may be anything (the engine clamps to `now`
    /// before calling); `seq` is strictly increasing across pushes.
    fn push(&mut self, time: SimTime, seq: u64, ev: E);
    /// Time of the next event, if any. May advance internal cursors but
    /// must not remove events.
    fn next_time(&mut self) -> Option<SimTime>;
    /// Remove and return the next event in `(time, seq)` order.
    fn pop(&mut self) -> Option<(SimTime, E)>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The reference queue: a plain binary heap over [`Entry`]. O(log n) per
/// operation and one heap node per event — kept as the behavioral oracle
/// for the timing wheel and for workloads with pathological time ranges.
pub struct HeapQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
}

impl<E> Default for HeapQueue<E> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new() }
    }
}

impl<E> EventQueue<E> for HeapQueue<E> {
    fn push(&mut self, time: SimTime, seq: u64, ev: E) {
        self.heap.push(Reverse(Entry { time, seq, ev }));
    }

    fn next_time(&mut self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.ev))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// The discrete-event engine. `Engine<E>` is wheel-backed; use
/// [`ReferenceEngine`] for the heap-backed oracle.
pub struct Engine<E, Q: EventQueue<E> = TimingWheel<E>> {
    queue: Q,
    now: SimTime,
    seq: u64,
    processed: u64,
    /// Scratch buffer threaded through `Schedule` on every dispatch so the
    /// hot loop never allocates.
    scratch: Vec<(SimTime, E)>,
}

/// Heap-backed engine, used as the determinism oracle in property tests.
pub type ReferenceEngine<E> = Engine<E, HeapQueue<E>>;

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    /// A timing-wheel-backed engine (the default, and the fast path).
    pub fn new() -> Self {
        Self::with_queue(TimingWheel::default())
    }
}

impl<E> Engine<E, HeapQueue<E>> {
    /// A heap-backed engine with identical observable behavior.
    pub fn new_reference() -> Self {
        Self::with_queue(HeapQueue::default())
    }
}

impl<E, Q: EventQueue<E>> Engine<E, Q> {
    /// Build an engine over an explicit queue implementation.
    pub fn with_queue(queue: Q) -> Self {
        Self { queue, now: 0, seq: 0, processed: 0, scratch: Vec::new() }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events processed so far (the perf counters report this).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Seed an event at absolute time `at`. Times in the past are clamped
    /// to `now` — the one documented behavior in every build profile
    /// (previously debug builds asserted while release silently clamped;
    /// the clamp matches [`Schedule::at`]).
    pub fn schedule(&mut self, at: SimTime, ev: E) {
        self.seq += 1;
        self.queue.push(at.max(self.now), self.seq, ev);
    }

    /// Run until the queue drains or the clock passes `horizon`.
    /// Events scheduled exactly at `horizon` still run; later ones do not.
    pub fn run_until<H: EventHandler<E>>(&mut self, handler: &mut H, horizon: SimTime) {
        let mut pending = std::mem::take(&mut self.scratch);
        while let Some(next) = self.queue.next_time() {
            if next > horizon {
                break;
            }
            // phoenix-lint: allow(panic_path): next_time() just returned Some, so the queue is non-empty
            let (t, ev) = self.queue.pop().expect("next_time reported an event");
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            self.processed += 1;
            let mut sched = Schedule { now: t, pending };
            handler.handle(ev, &mut sched);
            pending = sched.pending;
            // drain keeps the buffer's capacity for the next dispatch
            for (at, follow) in pending.drain(..) {
                self.seq += 1;
                self.queue.push(at, self.seq, follow);
            }
        }
        self.scratch = pending;
        // Clock lands on the horizon so post-run metrics read a full window
        // (not for the unbounded `run`, which ends at the last event).
        if horizon != SimTime::MAX && self.now < horizon {
            self.now = horizon;
        }
    }

    /// Drain everything (no horizon).
    pub fn run<H: EventHandler<E>>(&mut self, handler: &mut H) {
        self.run_until(handler, SimTime::MAX);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Clone)]
    enum Ev {
        Ping(u32),
        Chain(u32),
    }

    struct Recorder {
        seen: Vec<(SimTime, Ev)>,
    }

    impl EventHandler<Ev> for Recorder {
        fn handle(&mut self, ev: Ev, sched: &mut Schedule<Ev>) {
            self.seen.push((sched.now(), ev.clone()));
            if let Ev::Chain(n) = ev {
                if n > 0 {
                    sched.after(10, Ev::Chain(n - 1));
                }
            }
        }
    }

    #[test]
    fn delivers_in_time_order() {
        let mut eng = Engine::new();
        eng.schedule(30, Ev::Ping(3));
        eng.schedule(10, Ev::Ping(1));
        eng.schedule(20, Ev::Ping(2));
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        let times: Vec<SimTime> = rec.seen.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut eng = Engine::new();
        for i in 0..100 {
            eng.schedule(5, Ev::Ping(i));
        }
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        let ids: Vec<u32> = rec
            .seen
            .iter()
            .map(|(_, e)| match e {
                Ev::Ping(i) => *i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chained_scheduling_advances_clock() {
        let mut eng = Engine::new();
        eng.schedule(0, Ev::Chain(5));
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        assert_eq!(rec.seen.len(), 6);
        assert_eq!(eng.now(), 50);
        assert_eq!(eng.processed(), 6);
    }

    #[test]
    fn horizon_stops_and_clock_lands_on_horizon() {
        let mut eng = Engine::new();
        eng.schedule(0, Ev::Chain(1000));
        let mut rec = Recorder { seen: vec![] };
        eng.run_until(&mut rec, 95);
        // events at t=0,10,...,90 ran; t=100 did not
        assert_eq!(rec.seen.len(), 10);
        assert_eq!(eng.now(), 95);
        assert!(!eng.is_empty());
    }

    #[test]
    fn event_at_horizon_runs() {
        let mut eng = Engine::new();
        eng.schedule(50, Ev::Ping(1));
        let mut rec = Recorder { seen: vec![] };
        eng.run_until(&mut rec, 50);
        assert_eq!(rec.seen.len(), 1);
    }

    /// Regression for the old debug/release divergence: `schedule` into the
    /// past must clamp to `now` in every build, not assert in debug.
    #[test]
    fn past_scheduling_clamps_to_now_in_all_builds() {
        let mut eng = Engine::new();
        eng.schedule(50, Ev::Ping(1));
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        assert_eq!(eng.now(), 50);
        eng.schedule(10, Ev::Ping(2)); // in the past — clamps, never panics
        eng.run(&mut rec);
        assert_eq!(rec.seen.last().unwrap(), &(50, Ev::Ping(2)));
        assert_eq!(eng.processed(), 2);
    }

    /// The heap-backed oracle behaves identically on the basics.
    #[test]
    fn reference_engine_matches_on_basics() {
        let mut eng: ReferenceEngine<Ev> = Engine::new_reference();
        eng.schedule(0, Ev::Chain(5));
        for i in 0..10 {
            eng.schedule(25, Ev::Ping(i));
        }
        let mut rec = Recorder { seen: vec![] };
        eng.run_until(&mut rec, 40);
        assert_eq!(eng.now(), 40);
        // chain events at 0,10,20 then the ping storm at 25, then 30, 40
        let times: Vec<SimTime> = rec.seen.iter().map(|(t, _)| *t).collect();
        assert_eq!(times[..3], [0, 10, 20]);
        assert!(times[3..13].iter().all(|&t| t == 25));
        assert_eq!(times[13..], [30, 40]);
        eng.schedule(5, Ev::Ping(99)); // past: clamps to 40
        eng.run(&mut rec);
        assert_eq!(rec.seen.last().unwrap(), &(40, Ev::Ping(99)));
    }
}
