//! The event heap and dispatch loop.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::SimTime;

/// Handle used by handlers to schedule further events.
pub struct Schedule<E> {
    now: SimTime,
    pending: Vec<(SimTime, E)>,
}

impl<E> Schedule<E> {
    /// Current simulation time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (clamped to now — events may not
    /// be scheduled in the past).
    pub fn at(&mut self, at: SimTime, ev: E) {
        self.pending.push((at.max(self.now), ev));
    }

    /// Schedule `ev` after `delay` seconds.
    pub fn after(&mut self, delay: u64, ev: E) {
        self.pending.push((self.now + delay, ev));
    }
}

/// Implemented by the simulation model; the engine is generic over the
/// event type so each experiment defines its own compact enum.
pub trait EventHandler<E> {
    /// Process one event; schedule follow-ups through `sched`.
    fn handle(&mut self, ev: E, sched: &mut Schedule<E>);
}

struct Entry<E> {
    time: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// The discrete-event engine.
pub struct Engine<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events processed so far (the perf counters report this).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Seed an event at absolute time `at`.
    pub fn schedule(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.seq += 1;
        self.heap.push(Reverse(Entry { time: at.max(self.now), seq: self.seq, ev }));
    }

    /// Run until the queue drains or the clock passes `horizon`.
    /// Events scheduled exactly at `horizon` still run; later ones do not.
    pub fn run_until<H: EventHandler<E>>(&mut self, handler: &mut H, horizon: SimTime) {
        while let Some(Reverse(head)) = self.heap.peek() {
            if head.time > horizon {
                break;
            }
            let Reverse(entry) = self.heap.pop().unwrap();
            debug_assert!(entry.time >= self.now, "time went backwards");
            self.now = entry.time;
            self.processed += 1;
            let mut sched = Schedule { now: self.now, pending: Vec::new() };
            handler.handle(entry.ev, &mut sched);
            for (t, ev) in sched.pending {
                self.seq += 1;
                self.heap.push(Reverse(Entry { time: t, seq: self.seq, ev }));
            }
        }
        // Clock lands on the horizon so post-run metrics read a full window
        // (not for the unbounded `run`, which ends at the last event).
        if horizon != SimTime::MAX && self.now < horizon {
            self.now = horizon;
        }
    }

    /// Drain everything (no horizon).
    pub fn run<H: EventHandler<E>>(&mut self, handler: &mut H) {
        self.run_until(handler, SimTime::MAX);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq, Clone)]
    enum Ev {
        Ping(u32),
        Chain(u32),
    }

    struct Recorder {
        seen: Vec<(SimTime, Ev)>,
    }

    impl EventHandler<Ev> for Recorder {
        fn handle(&mut self, ev: Ev, sched: &mut Schedule<Ev>) {
            self.seen.push((sched.now(), ev.clone()));
            if let Ev::Chain(n) = ev {
                if n > 0 {
                    sched.after(10, Ev::Chain(n - 1));
                }
            }
        }
    }

    #[test]
    fn delivers_in_time_order() {
        let mut eng = Engine::new();
        eng.schedule(30, Ev::Ping(3));
        eng.schedule(10, Ev::Ping(1));
        eng.schedule(20, Ev::Ping(2));
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        let times: Vec<SimTime> = rec.seen.iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut eng = Engine::new();
        for i in 0..100 {
            eng.schedule(5, Ev::Ping(i));
        }
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        let ids: Vec<u32> = rec
            .seen
            .iter()
            .map(|(_, e)| match e {
                Ev::Ping(i) => *i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chained_scheduling_advances_clock() {
        let mut eng = Engine::new();
        eng.schedule(0, Ev::Chain(5));
        let mut rec = Recorder { seen: vec![] };
        eng.run(&mut rec);
        assert_eq!(rec.seen.len(), 6);
        assert_eq!(eng.now(), 50);
        assert_eq!(eng.processed(), 6);
    }

    #[test]
    fn horizon_stops_and_clock_lands_on_horizon() {
        let mut eng = Engine::new();
        eng.schedule(0, Ev::Chain(1000));
        let mut rec = Recorder { seen: vec![] };
        eng.run_until(&mut rec, 95);
        // events at t=0,10,...,90 ran; t=100 did not
        assert_eq!(rec.seen.len(), 10);
        assert_eq!(eng.now(), 95);
        assert!(!eng.is_empty());
    }

    #[test]
    fn event_at_horizon_runs() {
        let mut eng = Engine::new();
        eng.schedule(50, Ev::Ping(1));
        let mut rec = Recorder { seen: vec![] };
        eng.run_until(&mut rec, 50);
        assert_eq!(rec.seen.len(), 1);
    }
}
