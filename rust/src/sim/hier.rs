//! Hierarchical timing wheel: two lazily-rotated levels plus an overflow
//! heap, so month-long horizons never touch the `BinaryHeap`.
//!
//! Layout: level 0 is a window of [`SLOTS`] one-second slots (exactly the
//! PR-1 wheel); level 1 is a ring of [`SLOTS`] coarse slots, each covering
//! [`SLOTS`] seconds (~68 min), for a combined span of [`L1_SPAN`] seconds
//! (~194 days). The L0 window is always aligned to an L1 slot boundary:
//! `l0_start = l1_base + k·SLOTS` for the most recently cascaded L1 slot
//! `k`. When L0 drains, the next occupied L1 slot *cascades* — its events
//! are distributed into L0 slots and the window advances to that slot's
//! range. Only events farther than ~194 days (or post-jump stragglers)
//! ever reach the overflow heap.
//!
//! FIFO proof sketch (the differential harness in
//! `tests/engine_differential.rs` checks it exhaustively): the engine
//! assigns strictly increasing `seq`s, and every path appends in `seq`
//! order — direct pushes append; an L1 slot's vec is in push order, so for
//! any fixed timestamp its subsequence is `seq`-ascending, and cascading
//! distributes the vec in that order; heap migration pops in `(time, seq)`
//! order and always happens while L1 is empty, so migrated events precede
//! any later direct push (whose `seq` is necessarily larger). Slot and
//! batch vectors recycle their capacity (the cascade hands each drained
//! L1 vec back to its slot), so steady state allocates nothing.
//!
//! Alignment invariant: `l0_start = l1_base + (cursor1 − 1)·SLOTS`
//! whenever pushes can observe the wheel, which makes any in-span push
//! beyond the L0 window land at an L1 index `≥ cursor1` — the L1 cursor
//! never rewinds and each coarse slot cascades at most once per lap.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::engine::{Entry, EventQueue};
use super::SimTime;

/// Slots per level (both levels). L0 slots are one second; L1 slots are
/// `SLOTS` seconds.
const SLOTS: usize = 4096;
const WORDS: usize = SLOTS / 64;
/// Seconds covered by L0 + L1 together from `l1_base`.
const L1_SPAN: u64 = (SLOTS as u64) * (SLOTS as u64);

/// The hierarchical wheel. See the module docs for the invariants.
pub struct HierWheel<E> {
    /// `l0[i]` holds the events at time `l0_start + i`, in seq order.
    l0: Vec<Vec<E>>,
    bits0: [u64; WORDS],
    /// Next L0 slot to inspect; rewinds only onto provably-empty slots.
    cursor0: usize,
    /// Simulation time of L0 slot 0 (always `l1_base + k·SLOTS`).
    l0_start: SimTime,
    /// `l1[j]` holds the events in `[l1_base + j·SLOTS, +SLOTS)`, in push
    /// order, each tagged with its exact time for the cascade.
    l1: Vec<Vec<(SimTime, E)>>,
    bits1: [u64; WORDS],
    /// Next L1 slot to consider cascading; never rewinds (see module doc).
    cursor1: usize,
    /// Simulation time of L1 slot 0 (aligned to a `SLOTS` boundary).
    l1_base: SimTime,
    /// Batch being drained, reversed so `pop` takes from the back in FIFO
    /// order without shifting.
    active: Vec<E>,
    active_time: SimTime,
    /// Beyond-span events and post-jump stragglers, in `(time, seq)` order.
    overflow: BinaryHeap<Reverse<Entry<E>>>,
    len: usize,
}

impl<E> Default for HierWheel<E> {
    fn default() -> Self {
        Self {
            l0: std::iter::repeat_with(Vec::new).take(SLOTS).collect(),
            bits0: [0; WORDS],
            cursor0: 0,
            l0_start: 0,
            l1: std::iter::repeat_with(Vec::new).take(SLOTS).collect(),
            bits1: [0; WORDS],
            // L1 slot 0 is "pre-cascaded" into the initial L0 window
            // ([0, SLOTS)), keeping the alignment invariant from the start.
            cursor1: 1,
            l1_base: 0,
            active: Vec::new(),
            active_time: 0,
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }
}

/// First set bit at or after `from`, via a word scan.
fn scan_bits(bits: &[u64; WORDS], from: usize) -> Option<usize> {
    if from >= SLOTS {
        return None;
    }
    let mut w = from / 64;
    let mut word = bits[w] & (!0u64 << (from % 64));
    loop {
        if word != 0 {
            return Some(w * 64 + word.trailing_zeros() as usize);
        }
        w += 1;
        if w == WORDS {
            return None;
        }
        word = bits[w];
    }
}

impl<E> HierWheel<E> {
    #[inline]
    fn set_bit0(&mut self, i: usize) {
        self.bits0[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    fn clear_bit0(&mut self, i: usize) {
        self.bits0[i / 64] &= !(1 << (i % 64));
    }

    #[inline]
    fn set_bit1(&mut self, i: usize) {
        self.bits1[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    fn clear_bit1(&mut self, i: usize) {
        self.bits1[i / 64] &= !(1 << (i % 64));
    }

    /// Distribute L1 slot `j` into L0 and advance the window to its range.
    /// Precondition: L0 is empty (its scan just failed).
    fn cascade(&mut self, j: usize) {
        let slot_start = self.l1_base + (j as u64) * SLOTS as u64;
        let mut batch = std::mem::take(&mut self.l1[j]);
        self.clear_bit1(j);
        self.l0_start = slot_start;
        self.cursor0 = 0;
        self.cursor1 = j + 1;
        for (time, ev) in batch.drain(..) {
            debug_assert!(time >= slot_start && time - slot_start < SLOTS as u64);
            let idx = (time - slot_start) as usize;
            self.l0[idx].push(ev);
            self.set_bit0(idx);
        }
        // hand the drained allocation back to the slot (capacity recycles)
        self.l1[j] = batch;
    }

    /// Time and payload of the head event without removing it (positions
    /// the cursors exactly like [`EventQueue::next_time`]).
    pub fn peek(&mut self) -> Option<(SimTime, &E)> {
        let t = self.next_time()?;
        if !self.active.is_empty() {
            return self.active.last().map(|ev| (t, ev));
        }
        if let Some(Reverse(e)) = self.overflow.peek() {
            if e.time < self.l0_start {
                return Some((e.time, &e.ev));
            }
        }
        self.l0[self.cursor0].first().map(|ev| (t, ev))
    }
}

impl<E> EventQueue<E> for HierWheel<E> {
    fn push(&mut self, time: SimTime, seq: u64, ev: E) {
        self.len += 1;
        if time < self.l0_start {
            // the window already moved past `time` (idle jump between
            // runs); deliver through the overflow heap, which next_time
            // checks before both levels
            self.overflow.push(Reverse(Entry { time, seq, ev }));
            return;
        }
        let offset = time - self.l0_start;
        if offset < SLOTS as u64 {
            let idx = offset as usize;
            self.l0[idx].push(ev);
            self.set_bit0(idx);
            if idx < self.cursor0 {
                // every slot in [idx, cursor0) was scanned empty
                self.cursor0 = idx;
            }
            return;
        }
        // beyond the L0 window; `time >= l0_start` makes the L1 offset
        // well-defined, and the alignment invariant makes j >= cursor1
        if time - self.l1_base < L1_SPAN {
            let j = ((time - self.l1_base) / SLOTS as u64) as usize;
            debug_assert!(j >= self.cursor1, "L1 cursor would rewind");
            self.l1[j].push((time, ev));
            self.set_bit1(j);
            return;
        }
        self.overflow.push(Reverse(Entry { time, seq, ev }));
    }

    fn next_time(&mut self) -> Option<SimTime> {
        loop {
            if !self.active.is_empty() {
                return Some(self.active_time);
            }
            // stragglers are strictly earlier than everything in either
            // level (L0 times >= l0_start, L1 times >= l0_start + SLOTS)
            if let Some(Reverse(e)) = self.overflow.peek() {
                if e.time < self.l0_start {
                    return Some(e.time);
                }
            }
            if let Some(idx) = scan_bits(&self.bits0, self.cursor0) {
                self.cursor0 = idx;
                return Some(self.l0_start + idx as u64);
            }
            // L0 drained: cascade the next occupied coarse slot
            if let Some(j) = scan_bits(&self.bits1, self.cursor1) {
                self.cascade(j);
                continue; // the L0 scan now finds a slot
            }
            // both levels drained: jump to the earliest overflow event
            // (aligned down to a coarse-slot boundary) and migrate
            // everything within the new span into L1
            let head_time = match self.overflow.peek() {
                Some(Reverse(e)) => e.time,
                None => return None,
            };
            self.l1_base = head_time - head_time % SLOTS as u64;
            self.l0_start = self.l1_base;
            self.cursor0 = 0;
            self.cursor1 = 0;
            while let Some(Reverse(e)) = self.overflow.peek() {
                // heap pops ascending from the new base, so the offset
                // cannot underflow; comparing offsets (never computing
                // `l1_base + L1_SPAN`) keeps times near `SimTime::MAX`
                // deliverable
                if e.time - self.l1_base >= L1_SPAN {
                    break;
                }
                // phoenix-lint: allow(panic_path): peeked non-empty just above; pop cannot fail
                let Reverse(e) = self.overflow.pop().unwrap();
                let j = ((e.time - self.l1_base) / SLOTS as u64) as usize;
                self.l1[j].push((e.time, e.ev));
                self.set_bit1(j);
            }
            // loop: the L1 scan finds the head's slot and cascades it,
            // restoring the alignment invariant before returning
        }
    }

    fn pop(&mut self) -> Option<(SimTime, E)> {
        loop {
            if let Some(ev) = self.active.pop() {
                self.len -= 1;
                return Some((self.active_time, ev));
            }
            let t = self.next_time()?;
            if let Some(Reverse(e)) = self.overflow.peek() {
                if e.time < self.l0_start {
                    // phoenix-lint: allow(panic_path): guarded by the peek on the line above
                    let Reverse(e) = self.overflow.pop().unwrap();
                    self.len -= 1;
                    return Some((e.time, e.ev));
                }
            }
            // cursor0 sits on the non-empty slot for `t`: swap the whole
            // slot into the active batch (batch-drain, capacity recycles)
            std::mem::swap(&mut self.l0[self.cursor0], &mut self.active);
            self.active.reverse();
            self.active_time = t;
            self.clear_bit0(self.cursor0);
        }
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = SLOTS as u64;

    fn drain(w: &mut HierWheel<&'static str>) -> Vec<(SimTime, &'static str)> {
        let mut out = Vec::new();
        while let Some(x) = w.pop() {
            out.push(x);
        }
        out
    }

    #[test]
    fn orders_within_window_and_fifo_on_ties() {
        let mut w = HierWheel::default();
        w.push(20, 1, "a");
        w.push(10, 2, "b");
        w.push(10, 3, "c");
        w.push(0, 4, "d");
        assert_eq!(w.len(), 4);
        assert_eq!(drain(&mut w), vec![(0, "d"), (10, "b"), (10, "c"), (20, "a")]);
        assert!(w.is_empty());
    }

    #[test]
    fn l1_events_cascade_without_touching_the_heap() {
        let mut w = HierWheel::default();
        // all within L1 span (~194 days) but far outside the L0 window
        w.push(10, 1, "near");
        w.push(S * 100 + 7, 2, "hours");
        w.push(S * 4000 + 1, 3, "months");
        assert_eq!(w.overflow.len(), 0, "in-span events must not hit the heap");
        assert_eq!(
            drain(&mut w),
            vec![(10, "near"), (S * 100 + 7, "hours"), (S * 4000 + 1, "months")]
        );
    }

    #[test]
    fn cascade_preserves_fifo_within_a_coarse_slot() {
        let mut w = HierWheel::default();
        // one coarse slot, several timestamps, pushed out of time order
        w.push(S * 2 + 30, 1, "b1");
        w.push(S * 2 + 10, 2, "a1");
        w.push(S * 2 + 30, 3, "b2");
        w.push(S * 2 + 10, 4, "a2");
        assert_eq!(
            drain(&mut w),
            vec![
                (S * 2 + 10, "a1"),
                (S * 2 + 10, "a2"),
                (S * 2 + 30, "b1"),
                (S * 2 + 30, "b2"),
            ]
        );
    }

    #[test]
    fn cascade_and_direct_pushes_interleave_fifo_on_equal_times() {
        let mut w = HierWheel::default();
        w.push(S + 5, 1, "first"); // parked in L1 slot 1
        assert_eq!(w.next_time(), Some(S + 5)); // cascade into the window
        w.push(S + 5, 2, "second"); // direct push into the cascaded slot
        assert_eq!(drain(&mut w), vec![(S + 5, "first"), (S + 5, "second")]);
    }

    #[test]
    fn window_and_span_boundaries_are_exact() {
        let mut w = HierWheel::default();
        w.push(S - 1, 1, "l0-last"); // last slot of the initial window
        w.push(S, 2, "l1-first"); // first L1-routed time
        w.push(L1_SPAN - 1, 3, "l1-last"); // last in-span second
        w.push(L1_SPAN, 4, "heap-first"); // first beyond-span second
        assert_eq!(w.overflow.len(), 1);
        assert_eq!(
            drain(&mut w),
            vec![
                (S - 1, "l0-last"),
                (S, "l1-first"),
                (L1_SPAN - 1, "l1-last"),
                (L1_SPAN, "heap-first"),
            ]
        );
    }

    #[test]
    fn far_future_overflows_and_migrates() {
        let mut w = HierWheel::default();
        w.push(10, 1, "near");
        w.push(L1_SPAN * 3 + 17, 2, "far");
        assert_eq!(w.pop(), Some((10, "near")));
        // still beyond the original span: overflow again
        w.push(L1_SPAN * 2, 3, "mid");
        assert_eq!(w.pop(), Some((L1_SPAN * 2, "mid")));
        assert_eq!(w.pop(), Some((L1_SPAN * 3 + 17, "far")));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn straggler_behind_a_jumped_window_is_delivered_first() {
        let mut w = HierWheel::default();
        w.push(L1_SPAN * 5, 1, "far");
        assert_eq!(w.next_time(), Some(L1_SPAN * 5)); // span jumped
        w.push(5, 2, "late");
        w.push(7, 3, "later");
        assert_eq!(
            drain(&mut w),
            vec![(5, "late"), (7, "later"), (L1_SPAN * 5, "far")]
        );
    }

    #[test]
    fn push_behind_cursor_rewinds() {
        let mut w = HierWheel::default();
        w.push(100, 1, "b");
        assert_eq!(w.next_time(), Some(100)); // cursor0 advanced to 100
        w.push(40, 2, "a");
        assert_eq!(drain(&mut w), vec![(40, "a"), (100, "b")]);
    }

    #[test]
    fn same_time_push_during_batch_drain_runs_after_batch() {
        let mut w = HierWheel::default();
        w.push(10, 1, "a");
        w.push(10, 2, "b");
        assert_eq!(w.pop(), Some((10, "a"))); // batch active
        w.push(10, 3, "c"); // same timestamp, mid-drain
        assert_eq!(w.pop(), Some((10, "b")));
        assert_eq!(w.pop(), Some((10, "c")));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn push_into_a_later_coarse_slot_mid_drain() {
        let mut w = HierWheel::default();
        w.push(S * 3 + 9, 1, "x");
        assert_eq!(w.pop(), Some((S * 3 + 9, "x"))); // window now at slot 3
        // beyond the new window but in span: must route to L1, not panic
        w.push(S * 7 + 2, 2, "y");
        w.push(S * 3 + 100, 3, "z"); // still inside the current window
        assert_eq!(drain(&mut w), vec![(S * 3 + 100, "z"), (S * 7 + 2, "y")]);
    }

    #[test]
    fn delivers_events_at_time_max() {
        // regression: the aligned jump must keep times near SimTime::MAX
        // deliverable (MAX % SLOTS = 4095 lands in L1 slot 0)
        let mut w = HierWheel::default();
        w.push(10, 1, "near");
        w.push(u64::MAX, 2, "end-of-time");
        assert_eq!(w.pop(), Some((10, "near")));
        assert_eq!(w.pop(), Some((u64::MAX, "end-of-time")));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn peek_matches_pop_across_all_paths() {
        let mut w = HierWheel::default();
        w.push(3, 1, "a");
        w.push(S * 2 + 1, 2, "b");
        w.push(L1_SPAN + 5, 3, "c");
        while w.peek().is_some() {
            let (pt, &pe) = w.peek().unwrap();
            assert_eq!(w.pop(), Some((pt, pe)));
        }
        assert!(w.is_empty());
        // straggler path: jump far, then push behind the window
        w.push(L1_SPAN * 2, 4, "far");
        assert_eq!(w.next_time(), Some(L1_SPAN * 2));
        w.push(9, 5, "late");
        assert_eq!(w.peek().map(|(t, e)| (t, *e)), Some((9, "late")));
        assert_eq!(w.pop(), Some((9, "late")));
    }

    #[test]
    fn len_tracks_across_all_paths() {
        let mut w = HierWheel::default();
        w.push(1, 1, "a");
        w.push(S * 50, 2, "b");
        w.push(L1_SPAN + 3, 3, "c");
        assert_eq!(w.len(), 3);
        w.pop();
        assert_eq!(w.len(), 2);
        w.next_time();
        assert_eq!(w.len(), 2);
        drain(&mut w);
        assert_eq!(w.len(), 0);
    }
}
