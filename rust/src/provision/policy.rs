//! Resource provisioning policies (§II-B) for N departments.
//!
//! The paper evaluates one cooperative policy over exactly two
//! departments; this module generalizes it into an object-safe
//! [`ProvisionPolicy`] trait over any number of departments (the
//! K-department setting of arXiv:1006.1401 / arXiv:1004.1276) and ships
//! five implementations:
//!
//! * [`Cooperative`] — the paper's policy: service departments have
//!   absolute priority, all idle nodes flow to the batch departments,
//!   urgent service claims force batch returns.
//! * [`StaticPartition`] — hard per-department quotas, no flow between
//!   departments (models K dedicated clusters).
//! * [`ProportionalShare`] — each service department may claim only up to
//!   its cap; the rest is protected for batch work.
//! * [`LeaseBased`] — cooperative flow, but idle grants to batch
//!   departments carry a lease (arXiv:1006.1401's lease-style resizing):
//!   at expiry, idle leased nodes return to the free pool (busy ones
//!   renew), so urgent service claims can often be served without kills.
//! * [`TieredCooperative`] — departments are ranked into priority tiers;
//!   force-reclaims cascade down the tier order (a requester may only
//!   reclaim from strictly lower-priority departments).
//!
//! Two more implementations live in sibling modules, bringing the roster
//! to seven: per-tier *mixes* in [`crate::provision::mixed`]
//! ([`crate::provision::MixedPolicy`]) and the forecast-driven
//! [`crate::provision::Predictive`] policy in
//! [`crate::provision::predictive`], which pre-reserves free-pool
//! headroom ahead of predicted service ramps (see [`crate::forecast`]).
//!
//! # Implementing a custom policy
//!
//! Spell out the whole lifecycle surface — `on_join`/`on_leave` (dynamic
//! affiliation) and `on_crash`/`on_recover` (fault injection) — even when
//! a hook is a deliberate no-op; the in-tree lint (`cargo run -p
//! phoenix-lint`, rule R4) rejects impls that silently inherit them:
//!
//! ```
//! use phoenix_cloud::cluster::{DeptId, Ledger};
//! use phoenix_cloud::provision::{DeptProfile, ProvisionDecision, ProvisionPolicy};
//! use phoenix_cloud::sim::SimTime;
//!
//! /// Grants from the free pool only — never forces, never denies less.
//! #[derive(Debug)]
//! struct FreeOnly;
//!
//! impl ProvisionPolicy for FreeOnly {
//!     fn name(&self) -> &str {
//!         "free-only"
//!     }
//!
//!     fn on_request(
//!         &mut self,
//!         _dept: DeptId,
//!         need: u64,
//!         ledger: &Ledger,
//!         _now: SimTime,
//!     ) -> ProvisionDecision {
//!         let from_free = need.min(ledger.free());
//!         ProvisionDecision { from_free, force: Vec::new(), denied: need - from_free }
//!     }
//!
//!     fn idle_grants(
//!         &mut self,
//!         _ledger: &Ledger,
//!         _eligible: &[DeptId],
//!         _now: SimTime,
//!     ) -> Vec<(DeptId, u64)> {
//!         Vec::new() // hoard the free pool for future requests
//!     }
//!
//!     // profile-free policy: joins/leaves change nothing it tracks
//!     fn on_join(&mut self, _profile: DeptProfile, _now: SimTime) {}
//!     fn on_leave(&mut self, _dept: DeptId, _now: SimTime) {}
//!
//!     // stateless w.r.t. grants: the ledger already reflects the crash,
//!     // and recovered nodes re-enter via the free pool
//!     fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}
//!     fn on_recover(&mut self, _n: u64, _now: SimTime) {}
//! }
//!
//! let mut policy = FreeOnly;
//! let mut ledger = Ledger::new(10, 2);
//! ledger.grant(DeptId::ST, 8).unwrap(); // 2 left free
//! let d = policy.on_request(DeptId::WS, 5, &ledger, 0);
//! assert_eq!((d.from_free, d.denied), (2, 3));
//! assert!(d.force.is_empty());
//! ```

use std::collections::BTreeMap;
use std::fmt;

use crate::cluster::{DeptId, DeptKind, Ledger};
use crate::forecast::ForecastStats;
use crate::sim::SimTime;

use super::predictive::{Predictive, PredictiveSpec};

/// Static facts a policy knows about one department (from the
/// `[[department]]` config): identity, workload kind, priority tier, and
/// quota (partition size under [`StaticPartition`], claim cap under
/// [`ProportionalShare`], dedicated-cluster size in the scale sweep).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeptProfile {
    pub id: DeptId,
    pub kind: DeptKind,
    /// Priority tier: lower = higher priority ([`TieredCooperative`]).
    pub tier: u8,
    pub quota: u64,
}

/// What the policy decided for a request of `need` nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProvisionDecision {
    /// Granted straight from the free pool (applied by the RPS).
    pub from_free: u64,
    /// Per-department forced returns, in kill order: the driver kills jobs
    /// in each named department, then calls `Rps::complete_force`.
    pub force: Vec<(DeptId, u64)>,
    /// Demand the policy refused.
    pub denied: u64,
}

impl ProvisionDecision {
    fn none(denied: u64) -> Self {
        Self { from_free: 0, force: Vec::new(), denied }
    }

    /// Total nodes to be forcibly reclaimed across departments.
    pub fn force_total(&self) -> u64 {
        self.force.iter().map(|&(_, n)| n).sum()
    }

    /// Total nodes the requester will receive.
    pub fn granted(&self) -> u64 {
        self.from_free + self.force_total()
    }
}

/// An object-safe provisioning policy over an N-department ledger.
///
/// The Resource Provision Service consults the policy; the policy never
/// mutates the ledger itself. Every implementation must conserve nodes:
/// `from_free + force_total + denied == need`, `from_free ≤ ledger.free()`,
/// and each forced amount must not exceed the victim's holdings (the
/// property suite in `tests/properties.rs` enforces this for every
/// built-in policy).
pub trait ProvisionPolicy: fmt::Debug + Send {
    /// Short policy name for reports and CLI output.
    fn name(&self) -> &str;

    /// Department `dept` urgently requests `need` more nodes.
    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        now: SimTime,
    ) -> ProvisionDecision;

    /// Distribute the free pool across the `eligible` departments
    /// (normally every batch department; the driver narrows the set when
    /// only specific departments have queued demand). Entries must sum to
    /// at most `ledger.free()`.
    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        now: SimTime,
    ) -> Vec<(DeptId, u64)>;

    /// A department returned `n` nodes to the free pool (bookkeeping hook).
    fn on_release(&mut self, _dept: DeptId, _n: u64, _now: SimTime) {}

    /// `n` nodes were forcibly reclaimed from `victim` (bookkeeping hook —
    /// lease policies drop the forced nodes from their lease book so stale
    /// entries don't reclaim newer grants early or renew phantom nodes).
    fn on_force(&mut self, _victim: DeptId, _n: u64, _now: SimTime) {}

    /// Grants whose lease expired by `now`: (department, nodes) the RPS
    /// should try to pull back. The driver caps each reclaim by the
    /// department's idle nodes and reports the remainder through
    /// [`ProvisionPolicy::renewed`]. Default: nothing expires.
    fn expired(&mut self, _now: SimTime) -> Vec<(DeptId, u64)> {
        Vec::new()
    }

    /// `n` nodes of an expired lease stayed busy and renew for another
    /// term. Default: no-op.
    fn renewed(&mut self, _dept: DeptId, _n: u64, _now: SimTime) {}

    /// Earliest future time at which [`ProvisionPolicy::expired`] may
    /// return nodes (drives the simulator's lease-tick events).
    fn next_expiry(&self) -> Option<SimTime> {
        None
    }

    /// A department joined the shared cluster at runtime (dynamic
    /// affiliation, arXiv:1003.0958): start tracking its profile.
    /// Policies that key decisions on per-department profiles must
    /// implement this (all built-ins do); the default ignores the join,
    /// which is safe only for profile-free policies — unknown departments
    /// then fall under the policy's existing unknown-dept rules.
    fn on_join(&mut self, _profile: DeptProfile, _now: SimTime) {}

    /// A department left the cluster; its holdings were already released
    /// to the free pool. Built-ins drop the profile (and, for lease
    /// policies, any outstanding lease-book entries). Default: no-op.
    fn on_leave(&mut self, _dept: DeptId, _now: SimTime) {}

    /// `n` nodes crashed (fault injection, [`crate::faults`]): out of
    /// `holder`'s holdings, or out of the free pool when `holder` is
    /// `None`. The ledger move ([`Ledger::crash_held`] /
    /// [`Ledger::crash_free`]) has already happened; this is the
    /// bookkeeping hook — lease policies void the crashed nodes' lease
    /// entries so a lease can never fire for capacity that no longer
    /// exists. Default (for policies that track no per-grant state): no-op.
    fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}

    /// `n` repaired nodes returned to the free pool
    /// ([`Ledger::recover`]): the driver re-provisions them right after
    /// this hook, so stateless policies need nothing here. Default: no-op.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}

    /// One per-department demand sample: `util` in [0, 1+] and `demand`
    /// in nodes (service target or batch queue depth), fed every tick by
    /// both coordinators. Reactive policies ignore it; the forecast-driven
    /// [`Predictive`] policy trains its [`crate::forecast::DemandTracker`]
    /// here. Default: no-op.
    fn observe(&mut self, _dept: DeptId, _util: f64, _demand: u64, _now: SimTime) {}

    /// Forecast-quality counters (MAE, pre-grant hit rate) for the
    /// matrix/serve reports. Default: `None` — the policy forecasts
    /// nothing.
    fn forecast_stats(&self) -> Option<ForecastStats> {
        None
    }
}

/// Insert `p` into a profile roster, replacing any stale entry with the
/// same id (shared by every policy's `on_join`, including the mixed
/// combinator's).
pub(crate) fn upsert_profile(depts: &mut Vec<DeptProfile>, p: DeptProfile) {
    match depts.iter_mut().find(|e| e.id == p.id) {
        Some(slot) => *slot = p,
        None => depts.push(p),
    }
}

/// Drop department `id` from a profile roster (shared `on_leave` body).
pub(crate) fn remove_profile(depts: &mut Vec<DeptProfile>, id: DeptId) {
    depts.retain(|p| p.id != id);
}

/// Declarative policy selection — the parsed form of the `[policy]` config
/// section, turned into a live policy with [`PolicySpec::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicySpec {
    Cooperative,
    StaticPartition,
    ProportionalShare,
    Lease {
        /// Lease term in seconds.
        secs: u64,
    },
    Tiered,
    /// Forecast-driven reservation over the cooperative flow; the knobs
    /// come from the `[policy]` config section / CLI flags.
    Predictive(PredictiveSpec),
}

impl PolicySpec {
    /// Parse a policy name; `lease_secs` supplies the term for `lease`.
    /// `predictive` parses with the default knobs — config/CLI overlays
    /// patch the spec afterwards (see `ExperimentConfig::predictive`).
    pub fn parse(s: &str, lease_secs: u64) -> anyhow::Result<Self> {
        Ok(match s {
            "cooperative" | "coop" => PolicySpec::Cooperative,
            "static" => PolicySpec::StaticPartition,
            "proportional" => PolicySpec::ProportionalShare,
            "lease" => PolicySpec::Lease { secs: lease_secs },
            "tiered" => PolicySpec::Tiered,
            "predictive" => PolicySpec::Predictive(PredictiveSpec::default()),
            _ => anyhow::bail!(
                "unknown policy '{s}' (cooperative|static|proportional|lease|tiered|predictive)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicySpec::Cooperative => "cooperative",
            PolicySpec::StaticPartition => "static",
            PolicySpec::ProportionalShare => "proportional",
            PolicySpec::Lease { .. } => "lease",
            PolicySpec::Tiered => "tiered",
            PolicySpec::Predictive(_) => "predictive",
        }
    }

    /// Instantiate the policy over the given department profiles.
    pub fn build(&self, depts: &[DeptProfile]) -> Box<dyn ProvisionPolicy> {
        match *self {
            PolicySpec::Cooperative => Box::new(Cooperative::new(depts.to_vec())),
            PolicySpec::StaticPartition => Box::new(StaticPartition::new(depts.to_vec())),
            PolicySpec::ProportionalShare => {
                Box::new(ProportionalShare::new(depts.to_vec()))
            }
            PolicySpec::Lease { secs } => Box::new(LeaseBased::new(depts.to_vec(), secs)),
            PolicySpec::Tiered => Box::new(TieredCooperative::new(depts.to_vec())),
            PolicySpec::Predictive(spec) => Box::new(Predictive::new(depts.to_vec(), spec)),
        }
    }
}

// ---- shared helpers ---------------------------------------------------------

/// Force `shortfall` nodes out of `victims` (largest holdings first, ties
/// to the lower id — deterministic). Returns the per-department reclaim
/// list and the unmet remainder.
fn force_by_holdings(
    ledger: &Ledger,
    victims: &mut [&DeptProfile],
    mut shortfall: u64,
) -> (Vec<(DeptId, u64)>, u64) {
    victims.sort_by_key(|p| (std::cmp::Reverse(ledger.held(p.id)), p.id));
    let mut force = Vec::new();
    for p in victims.iter() {
        if shortfall == 0 {
            break;
        }
        let take = shortfall.min(ledger.held(p.id));
        if take > 0 {
            force.push((p.id, take));
            shortfall -= take;
        }
    }
    (force, shortfall)
}

/// Split `free` evenly across `eligible` (remainder to the earliest ids in
/// the given order); zero shares are dropped.
pub(crate) fn split_even(free: u64, eligible: &[DeptId]) -> Vec<(DeptId, u64)> {
    if free == 0 || eligible.is_empty() {
        return Vec::new();
    }
    let n = eligible.len() as u64;
    let share = free / n;
    let rem = free % n;
    eligible
        .iter()
        .enumerate()
        .map(|(i, &d)| (d, share + u64::from((i as u64) < rem)))
        .filter(|&(_, n)| n > 0)
        .collect()
}

fn batch_profiles(depts: &[DeptProfile]) -> Vec<&DeptProfile> {
    depts.iter().filter(|p| p.kind == DeptKind::Batch).collect()
}

pub(crate) fn profile(depts: &[DeptProfile], id: DeptId) -> Option<&DeptProfile> {
    depts.iter().find(|p| p.id == id)
}

/// The §II-B cooperative request flow shared by [`Cooperative`],
/// [`LeaseBased`], and [`Predictive`]: free pool first; a *service*
/// requester then forces the shortfall out of the batch departments
/// (largest holdings first); batch requesters never force.
pub(crate) fn cooperative_decision(
    depts: &[DeptProfile],
    dept: DeptId,
    need: u64,
    ledger: &Ledger,
) -> ProvisionDecision {
    let from_free = need.min(ledger.free());
    let shortfall = need - from_free;
    let requester_kind = profile(depts, dept).map(|p| p.kind);
    if shortfall == 0 || requester_kind != Some(DeptKind::Service) {
        // batch departments wait for idle capacity; they never force
        return ProvisionDecision { from_free, force: Vec::new(), denied: shortfall };
    }
    let mut victims: Vec<&DeptProfile> =
        batch_profiles(depts).into_iter().filter(|p| p.id != dept).collect();
    let (force, denied) = force_by_holdings(ledger, &mut victims, shortfall);
    ProvisionDecision { from_free, force, denied }
}

// ---- the paper's cooperative policy (§II-B), N departments ------------------

/// Service departments have absolute priority; all idle nodes flow to the
/// batch departments (split evenly when there are several); urgent service
/// claims force batch returns, largest batch holdings first.
#[derive(Debug)]
pub struct Cooperative {
    depts: Vec<DeptProfile>,
}

impl Cooperative {
    pub fn new(depts: Vec<DeptProfile>) -> Self {
        Self { depts }
    }
}

impl ProvisionPolicy for Cooperative {
    fn name(&self) -> &str {
        "cooperative"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        _now: SimTime,
    ) -> ProvisionDecision {
        cooperative_decision(&self.depts, dept, need, ledger)
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        _now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        // "if there are idle resources … provision all of them to ST"
        split_even(ledger.free(), eligible)
    }

    fn on_join(&mut self, profile: DeptProfile, _now: SimTime) {
        upsert_profile(&mut self.depts, profile);
    }

    fn on_leave(&mut self, dept: DeptId, _now: SimTime) {
        remove_profile(&mut self.depts, dept);
    }

    /// Deliberate no-op: cooperative keys every decision on the live
    /// ledger, which already reflects the crash; there is no per-grant
    /// state to void (lint rule R4 wants this spelled out, not inherited).
    fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}

    /// Deliberate no-op: recovered nodes re-enter via the free pool and
    /// the driver's re-provisioning pass.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}
}

// ---- static partition (the SC baseline), N departments ----------------------

/// Hard quotas: each department may hold at most its quota and nothing
/// flows between departments — K dedicated clusters sharing a chassis.
#[derive(Debug)]
pub struct StaticPartition {
    depts: Vec<DeptProfile>,
}

impl StaticPartition {
    pub fn new(depts: Vec<DeptProfile>) -> Self {
        Self { depts }
    }

    fn headroom(&self, dept: DeptId, ledger: &Ledger) -> u64 {
        profile(&self.depts, dept)
            .map(|p| p.quota.saturating_sub(ledger.held(dept)))
            .unwrap_or(0)
    }
}

impl ProvisionPolicy for StaticPartition {
    fn name(&self) -> &str {
        "static"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        _now: SimTime,
    ) -> ProvisionDecision {
        let grant = need.min(self.headroom(dept, ledger)).min(ledger.free());
        ProvisionDecision { from_free: grant, force: Vec::new(), denied: need - grant }
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        _now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        let mut remaining = ledger.free();
        let mut out = Vec::new();
        for &d in eligible {
            if remaining == 0 {
                break;
            }
            let give = self.headroom(d, ledger).min(remaining);
            if give > 0 {
                remaining -= give;
                out.push((d, give));
            }
        }
        out
    }

    fn on_join(&mut self, profile: DeptProfile, _now: SimTime) {
        upsert_profile(&mut self.depts, profile);
    }

    fn on_leave(&mut self, dept: DeptId, _now: SimTime) {
        remove_profile(&mut self.depts, dept);
    }

    /// Deliberate no-op: quotas are headroom checks against the live
    /// ledger; a crash shrinks holdings and headroom follows automatically.
    fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}

    /// Deliberate no-op: repaired nodes rejoin the free pool and are
    /// re-granted by the quota-capped `idle_grants` pass.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}
}

// ---- proportional share (ablation), N departments ---------------------------

/// Service departments may claim only up to their quota (cap); the rest of
/// the cluster is protected for batch work. Quantifies what absolute
/// service priority costs the batch departments.
#[derive(Debug)]
pub struct ProportionalShare {
    depts: Vec<DeptProfile>,
}

impl ProportionalShare {
    pub fn new(depts: Vec<DeptProfile>) -> Self {
        Self { depts }
    }
}

impl ProvisionPolicy for ProportionalShare {
    fn name(&self) -> &str {
        "proportional"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        _now: SimTime,
    ) -> ProvisionDecision {
        let Some(p) = profile(&self.depts, dept) else {
            return ProvisionDecision::none(need);
        };
        let allowed = p.quota.saturating_sub(ledger.held(dept)).min(need);
        let from_free = allowed.min(ledger.free());
        let shortfall = allowed - from_free;
        let (force, unmet) = if p.kind == DeptKind::Service && shortfall > 0 {
            let mut victims: Vec<&DeptProfile> = batch_profiles(&self.depts)
                .into_iter()
                .filter(|v| v.id != dept)
                .collect();
            force_by_holdings(ledger, &mut victims, shortfall)
        } else {
            (Vec::new(), shortfall)
        };
        let denied = (need - allowed) + unmet;
        ProvisionDecision { from_free, force, denied }
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        _now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        split_even(ledger.free(), eligible)
    }

    fn on_join(&mut self, profile: DeptProfile, _now: SimTime) {
        upsert_profile(&mut self.depts, profile);
    }

    fn on_leave(&mut self, dept: DeptId, _now: SimTime) {
        remove_profile(&mut self.depts, dept);
    }

    /// Deliberate no-op: like cooperative, decisions read the live ledger
    /// only; the service-priority force path needs no crash bookkeeping.
    fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}

    /// Deliberate no-op: recovery flows through the free pool.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}
}

// ---- lease-based cooperative (arXiv:1006.1401) ------------------------------

/// Cooperative flow with lease-style resizing: every idle grant to a batch
/// department expires after `lease` seconds. At expiry the driver returns
/// the department's *idle* leased nodes to the free pool (busy nodes renew
/// for another term), so the free pool periodically recovers capacity and
/// urgent service claims can often be served without killing jobs.
///
/// A zero-second term is a degenerate but well-defined edge (the
/// lease-term sensitivity grid in `experiments::matrix` sweeps toward it):
/// no node can be held on a lease of zero length, so every would-be leased
/// grant is *refused* — idle grants return empty, batch-side requests are
/// denied in full — rather than handed out untracked. Nothing is ever
/// recorded in the lease book, so nothing can leak (property-tested by
/// `prop_lease_zero_term_rejects_and_never_leaks`). Service-side requests
/// are unaffected: service holdings are never leased.
#[derive(Debug)]
pub struct LeaseBased {
    depts: Vec<DeptProfile>,
    lease: u64,
    /// Outstanding leases: expiry → per-department leased node counts.
    leases: BTreeMap<SimTime, BTreeMap<DeptId, u64>>,
}

impl LeaseBased {
    pub fn new(depts: Vec<DeptProfile>, lease: u64) -> Self {
        Self { depts, lease, leases: BTreeMap::new() }
    }

    pub fn lease_secs(&self) -> u64 {
        self.lease
    }

    fn record(&mut self, dept: DeptId, n: u64, now: SimTime) {
        if n > 0 {
            *self
                .leases
                .entry(now + self.lease)
                .or_default()
                .entry(dept)
                .or_insert(0) += n;
        }
    }

    /// Drop `n` of `dept`'s leased nodes from the book, earliest expiry
    /// first (forced-away nodes no longer belong to the department, so
    /// their lease entries must not fire later).
    fn drop_leased(&mut self, dept: DeptId, mut n: u64) {
        let expiries: Vec<SimTime> = self.leases.keys().copied().collect();
        for t in expiries {
            if n == 0 {
                break;
            }
            let Some(per_dept) = self.leases.get_mut(&t) else { continue };
            if let Some(held) = per_dept.get_mut(&dept) {
                let take = n.min(*held);
                *held -= take;
                n -= take;
                if *held == 0 {
                    per_dept.remove(&dept);
                }
            }
            if per_dept.is_empty() {
                self.leases.remove(&t);
            }
        }
    }
}

impl ProvisionPolicy for LeaseBased {
    fn name(&self) -> &str {
        "lease"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        now: SimTime,
    ) -> ProvisionDecision {
        let batch_requester =
            profile(&self.depts, dept).is_some_and(|p| p.kind == DeptKind::Batch);
        if self.lease == 0 && batch_requester {
            // a zero-length lease cannot hold any node: refuse instead of
            // granting capacity the lease book could never reclaim
            return ProvisionDecision::none(need);
        }
        // same flow as Cooperative, plus a lease on any batch-side grant
        let d = cooperative_decision(&self.depts, dept, need, ledger);
        if batch_requester {
            self.record(dept, d.from_free, now);
        }
        d
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        if self.lease == 0 {
            return Vec::new(); // see the zero-term note on [`LeaseBased`]
        }
        let grants = split_even(ledger.free(), eligible);
        for &(d, n) in &grants {
            self.record(d, n, now);
        }
        grants
    }

    fn expired(&mut self, now: SimTime) -> Vec<(DeptId, u64)> {
        let due: Vec<SimTime> = self.leases.range(..=now).map(|(&t, _)| t).collect();
        let mut total: BTreeMap<DeptId, u64> = BTreeMap::new();
        for t in due {
            if let Some(per_dept) = self.leases.remove(&t) {
                for (d, n) in per_dept {
                    *total.entry(d).or_insert(0) += n;
                }
            }
        }
        total.into_iter().collect()
    }

    fn renewed(&mut self, dept: DeptId, n: u64, now: SimTime) {
        self.record(dept, n, now);
    }

    fn on_force(&mut self, victim: DeptId, n: u64, _now: SimTime) {
        self.drop_leased(victim, n);
    }

    fn on_crash(&mut self, holder: Option<DeptId>, n: u64, _now: SimTime) {
        // a crash voids the victim's lease book exactly like a force:
        // the nodes are gone, so their lease entries must never fire
        // (earliest expiry first — same rule as on_force)
        if let Some(dept) = holder {
            self.drop_leased(dept, n);
        }
    }

    fn next_expiry(&self) -> Option<SimTime> {
        self.leases.keys().next().copied()
    }

    fn on_join(&mut self, profile: DeptProfile, _now: SimTime) {
        upsert_profile(&mut self.depts, profile);
    }

    fn on_leave(&mut self, dept: DeptId, _now: SimTime) {
        // a departed department's outstanding leases must never fire
        self.drop_leased(dept, u64::MAX);
        remove_profile(&mut self.depts, dept);
    }

    /// Deliberate no-op: crashed nodes already left the lease book via
    /// [`ProvisionPolicy::on_crash`]; repaired nodes re-enter the free
    /// pool and pick up fresh leases when re-granted.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}
}

// ---- priority-tiered cooperative --------------------------------------------

/// Cooperative flow with ranked departments: a requester may force-reclaim
/// only from *strictly lower-priority* departments (tier number greater
/// than its own), and the reclaim cascades from the bottom tier upward.
/// Within a tier, largest holdings go first (ties to the lower id).
#[derive(Debug)]
pub struct TieredCooperative {
    depts: Vec<DeptProfile>,
}

impl TieredCooperative {
    pub fn new(depts: Vec<DeptProfile>) -> Self {
        Self { depts }
    }
}

impl ProvisionPolicy for TieredCooperative {
    fn name(&self) -> &str {
        "tiered"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        _now: SimTime,
    ) -> ProvisionDecision {
        let from_free = need.min(ledger.free());
        let mut shortfall = need - from_free;
        let Some(requester) = profile(&self.depts, dept) else {
            return ProvisionDecision { from_free, force: Vec::new(), denied: shortfall };
        };
        if shortfall == 0 {
            return ProvisionDecision { from_free, force: Vec::new(), denied: 0 };
        }
        // cascade down the tiers: bottom (largest tier value) first
        let mut victims: Vec<&DeptProfile> = self
            .depts
            .iter()
            .filter(|p| p.kind == DeptKind::Batch && p.tier > requester.tier && p.id != dept)
            .collect();
        victims.sort_by_key(|p| {
            (std::cmp::Reverse(p.tier), std::cmp::Reverse(ledger.held(p.id)), p.id)
        });
        let mut force = Vec::new();
        for p in victims {
            if shortfall == 0 {
                break;
            }
            let take = shortfall.min(ledger.held(p.id));
            if take > 0 {
                force.push((p.id, take));
                shortfall -= take;
            }
        }
        ProvisionDecision { from_free, force, denied: shortfall }
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        _now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        // idle capacity favors higher-priority batch departments: the
        // highest-priority (lowest-tier) eligible group splits the whole
        // pool evenly; lower tiers see idle capacity only when no
        // higher-priority department is eligible for it
        let mut by_tier: Vec<(u8, DeptId)> = eligible
            .iter()
            .map(|&d| (profile(&self.depts, d).map(|p| p.tier).unwrap_or(u8::MAX), d))
            .collect();
        by_tier.sort();
        let Some(&(top, _)) = by_tier.first() else {
            return Vec::new();
        };
        let group: Vec<DeptId> = by_tier
            .iter()
            .take_while(|&&(t, _)| t == top)
            .map(|&(_, d)| d)
            .collect();
        split_even(ledger.free(), &group)
    }

    fn on_join(&mut self, profile: DeptProfile, _now: SimTime) {
        upsert_profile(&mut self.depts, profile);
    }

    fn on_leave(&mut self, dept: DeptId, _now: SimTime) {
        remove_profile(&mut self.depts, dept);
    }

    /// Deliberate no-op: tier ranking reads the live ledger per decision;
    /// a crash shrinks the victim's holdings and the cascade adapts.
    fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}

    /// Deliberate no-op: repaired nodes rejoin the free pool and flow to
    /// the top eligible tier on the next `idle_grants` pass.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}
}

// ---- convenience constructors -----------------------------------------------

/// The paper's two-department profile set: ST (batch, id 0) + WS (service,
/// id 1) with the given quotas (partition sizes / caps).
pub fn two_dept_profiles(st_quota: u64, ws_quota: u64) -> Vec<DeptProfile> {
    vec![
        DeptProfile { id: DeptId::ST, kind: DeptKind::Batch, tier: 1, quota: st_quota },
        DeptProfile { id: DeptId::WS, kind: DeptKind::Service, tier: 0, quota: ws_quota },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger(free: u64, st: u64, ws: u64) -> Ledger {
        let mut l = Ledger::new(free + st + ws, 2);
        l.grant(DeptId::ST, st).unwrap();
        l.grant(DeptId::WS, ws).unwrap();
        l
    }

    #[test]
    fn cooperative_prefers_free_then_forces() {
        let l = ledger(10, 50, 5);
        let mut p = Cooperative::new(two_dept_profiles(144, 64));
        let d = p.on_request(DeptId::WS, 25, &l, 0);
        assert_eq!(d.from_free, 10);
        assert_eq!(d.force, vec![(DeptId::ST, 15)]);
        assert_eq!(d.denied, 0);
    }

    #[test]
    fn cooperative_denies_only_when_cluster_exhausted() {
        let l = ledger(0, 10, 5);
        let mut p = Cooperative::new(two_dept_profiles(144, 64));
        let d = p.on_request(DeptId::WS, 25, &l, 0);
        assert_eq!(d.force_total(), 10);
        assert_eq!(d.denied, 15);
    }

    #[test]
    fn cooperative_gives_all_idle_to_single_batch_dept() {
        let l = ledger(42, 0, 0);
        let mut p = Cooperative::new(two_dept_profiles(144, 64));
        assert_eq!(p.idle_grants(&l, &[DeptId::ST], 0), vec![(DeptId::ST, 42)]);
    }

    #[test]
    fn cooperative_splits_idle_across_batch_depts() {
        let mut l = Ledger::new(10, 3);
        l.grant(DeptId(2), 3).unwrap(); // 7 free
        let depts = vec![
            DeptProfile { id: DeptId(0), kind: DeptKind::Batch, tier: 1, quota: 100 },
            DeptProfile { id: DeptId(1), kind: DeptKind::Batch, tier: 1, quota: 100 },
            DeptProfile { id: DeptId(2), kind: DeptKind::Service, tier: 0, quota: 100 },
        ];
        let mut p = Cooperative::new(depts);
        let grants = p.idle_grants(&l, &[DeptId(0), DeptId(1)], 0);
        assert_eq!(grants, vec![(DeptId(0), 4), (DeptId(1), 3)]);
    }

    #[test]
    fn cooperative_forces_largest_batch_holder_first() {
        let mut l = Ledger::new(30, 3);
        l.grant(DeptId(0), 10).unwrap();
        l.grant(DeptId(1), 20).unwrap();
        let depts = vec![
            DeptProfile { id: DeptId(0), kind: DeptKind::Batch, tier: 1, quota: 100 },
            DeptProfile { id: DeptId(1), kind: DeptKind::Batch, tier: 1, quota: 100 },
            DeptProfile { id: DeptId(2), kind: DeptKind::Service, tier: 0, quota: 100 },
        ];
        let mut p = Cooperative::new(depts);
        let d = p.on_request(DeptId(2), 25, &l, 0);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force, vec![(DeptId(1), 20), (DeptId(0), 5)]);
        assert_eq!(d.denied, 0);
    }

    #[test]
    fn batch_requester_never_forces() {
        let l = ledger(2, 0, 30);
        let mut p = Cooperative::new(two_dept_profiles(144, 64));
        let d = p.on_request(DeptId::ST, 10, &l, 0);
        assert_eq!(d.from_free, 2);
        assert!(d.force.is_empty());
        assert_eq!(d.denied, 8);
    }

    #[test]
    fn static_partition_caps_both_sides() {
        let p_depts = two_dept_profiles(144, 64);
        let mut p = StaticPartition::new(p_depts);
        let l = ledger(144 + 14, 0, 50); // ws holds 50 of its 64
        let d = p.on_request(DeptId::WS, 30, &l, 0);
        assert_eq!(d.from_free, 14);
        assert!(d.force.is_empty());
        assert_eq!(d.denied, 16);
        // ST fills only to its partition
        let l2 = ledger(200, 100, 0);
        assert_eq!(p.idle_grants(&l2, &[DeptId::ST], 0), vec![(DeptId::ST, 44)]);
    }

    #[test]
    fn proportional_share_caps_service() {
        let mut depts = two_dept_profiles(144, 40);
        depts[0].quota = u64::MAX; // batch uncapped
        let mut p = ProportionalShare::new(depts);
        let l = ledger(0, 100, 30);
        let d = p.on_request(DeptId::WS, 30, &l, 0);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force, vec![(DeptId::ST, 10)]); // only up to the 40-node cap
        assert_eq!(d.denied, 20);
    }

    #[test]
    fn lease_records_and_expires_grants() {
        let mut p = LeaseBased::new(two_dept_profiles(144, 64), 100);
        let l = ledger(50, 0, 0);
        let grants = p.idle_grants(&l, &[DeptId::ST], 10);
        assert_eq!(grants, vec![(DeptId::ST, 50)]);
        assert_eq!(p.next_expiry(), Some(110));
        assert!(p.expired(109).is_empty());
        assert_eq!(p.expired(110), vec![(DeptId::ST, 50)]);
        assert_eq!(p.next_expiry(), None);
        // busy nodes renew for another term
        p.renewed(DeptId::ST, 30, 110);
        assert_eq!(p.next_expiry(), Some(210));
        assert_eq!(p.expired(500), vec![(DeptId::ST, 30)]);
    }

    #[test]
    fn lease_requests_force_like_cooperative() {
        let mut p = LeaseBased::new(two_dept_profiles(144, 64), 100);
        let l = ledger(4, 20, 0);
        let d = p.on_request(DeptId::WS, 10, &l, 0);
        assert_eq!(d.from_free, 4);
        assert_eq!(d.force, vec![(DeptId::ST, 6)]);
        assert_eq!(d.denied, 0);
    }

    #[test]
    fn forced_nodes_leave_the_lease_book() {
        let mut p = LeaseBased::new(two_dept_profiles(144, 64), 100);
        let l = ledger(10, 0, 0);
        p.idle_grants(&l, &[DeptId::ST], 0); // 10 leased, expiry 100
        // a service spike forces all 10 away before the lease ends
        p.on_force(DeptId::ST, 10, 50);
        assert_eq!(p.next_expiry(), None, "stale lease survived the force");
        assert!(p.expired(1000).is_empty());
        // partial force drops from the earliest expiry first
        let l2 = ledger(6, 0, 0);
        p.idle_grants(&l2, &[DeptId::ST], 200); // expiry 300
        let l3 = ledger(4, 6, 0);
        p.idle_grants(&l3, &[DeptId::ST], 250); // expiry 350
        p.on_force(DeptId::ST, 7, 260); // kills the 6 at 300 + 1 of the 4
        assert_eq!(p.next_expiry(), Some(350));
        assert_eq!(p.expired(350), vec![(DeptId::ST, 3)]);
    }

    #[test]
    fn lease_aggregates_same_expiry() {
        let mut p = LeaseBased::new(two_dept_profiles(144, 64), 60);
        let l = ledger(10, 0, 0);
        p.idle_grants(&l, &[DeptId::ST], 0);
        let l2 = ledger(5, 10, 0);
        p.idle_grants(&l2, &[DeptId::ST], 0);
        assert_eq!(p.expired(60), vec![(DeptId::ST, 15)]);
    }

    fn tiered_depts() -> Vec<DeptProfile> {
        vec![
            DeptProfile { id: DeptId(0), kind: DeptKind::Service, tier: 0, quota: 100 },
            DeptProfile { id: DeptId(1), kind: DeptKind::Batch, tier: 1, quota: 100 },
            DeptProfile { id: DeptId(2), kind: DeptKind::Batch, tier: 2, quota: 100 },
        ]
    }

    #[test]
    fn tiered_cascades_down_from_the_bottom_tier() {
        let mut l = Ledger::new(25, 3);
        l.grant(DeptId(1), 15).unwrap();
        l.grant(DeptId(2), 10).unwrap();
        let mut p = TieredCooperative::new(tiered_depts());
        // top-tier service dept reclaims tier 2 fully before touching tier 1
        let d = p.on_request(DeptId(0), 18, &l, 0);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force, vec![(DeptId(2), 10), (DeptId(1), 8)]);
        assert_eq!(d.denied, 0);
    }

    #[test]
    fn tiered_never_reclaims_upward_or_sideways() {
        let mut l = Ledger::new(30, 3);
        l.grant(DeptId(1), 15).unwrap();
        l.grant(DeptId(2), 15).unwrap();
        let mut p = TieredCooperative::new(tiered_depts());
        // the tier-2 batch dept outranks nobody: nothing to force
        let d = p.on_request(DeptId(2), 10, &l, 0);
        assert!(d.force.is_empty());
        assert_eq!(d.denied, 10);
        // the tier-1 batch dept may only reclaim from tier 2
        let d = p.on_request(DeptId(1), 20, &l, 0);
        assert_eq!(d.force, vec![(DeptId(2), 15)]);
        assert_eq!(d.denied, 5);
    }

    #[test]
    fn tiered_idle_fills_top_tier_first() {
        let l = {
            let mut l = Ledger::new(10, 3);
            l.grant(DeptId(0), 0).unwrap();
            l
        };
        let mut p = TieredCooperative::new(tiered_depts());
        let grants = p.idle_grants(&l, &[DeptId(1), DeptId(2)], 0);
        // tier 1 takes everything before tier 2 sees any
        assert_eq!(grants, vec![(DeptId(1), 10)]);
    }

    #[test]
    fn spec_parses_and_builds_every_policy() {
        let depts = two_dept_profiles(144, 64);
        for (name, expect) in [
            ("cooperative", PolicySpec::Cooperative),
            ("static", PolicySpec::StaticPartition),
            ("proportional", PolicySpec::ProportionalShare),
            ("lease", PolicySpec::Lease { secs: 300 }),
            ("tiered", PolicySpec::Tiered),
            ("predictive", PolicySpec::Predictive(PredictiveSpec::default())),
        ] {
            let spec = PolicySpec::parse(name, 300).unwrap();
            assert_eq!(spec, expect);
            assert_eq!(spec.name(), name);
            let built = spec.build(&depts);
            assert_eq!(built.name(), name);
        }
        assert!(PolicySpec::parse("lottery", 300).is_err());
    }

    #[test]
    fn join_and_leave_update_every_policy_roster() {
        // a third (batch) department joins at runtime, becomes a force
        // victim, then leaves again
        let joiner = DeptProfile { id: DeptId(2), kind: DeptKind::Batch, tier: 1, quota: 30 };
        for spec in [
            PolicySpec::Cooperative,
            PolicySpec::StaticPartition,
            PolicySpec::ProportionalShare,
            PolicySpec::Lease { secs: 60 },
            PolicySpec::Tiered,
            PolicySpec::Predictive(PredictiveSpec::default()),
        ] {
            let mut p = spec.build(&two_dept_profiles(144, 64));
            p.on_join(joiner, 10);
            let mut l = Ledger::new(40, 3);
            l.grant(DeptId(2), 25).unwrap(); // the joiner holds 25, 15 free
            // a service claim may now reclaim from the joiner under the
            // force-capable policies
            let d = p.on_request(DeptId::WS, 40, &l, 20);
            assert_eq!(
                d.from_free + d.force_total() + d.denied,
                40,
                "{}: joiner broke conservation: {d:?}",
                p.name()
            );
            if matches!(spec, PolicySpec::Cooperative | PolicySpec::Lease { .. }) {
                assert!(
                    d.force.iter().any(|&(v, _)| v == DeptId(2)),
                    "{}: joined dept never became a victim: {d:?}",
                    p.name()
                );
            }
            // after leave, the policy must stop naming the department
            p.on_leave(DeptId(2), 30);
            let d = p.on_request(DeptId::WS, 40, &l, 40);
            assert!(
                d.force.iter().all(|&(v, _)| v != DeptId(2)),
                "{}: departed dept still a victim: {d:?}",
                p.name()
            );
        }
        // a leaving lease-holder takes its lease-book entries with it
        let mut p = LeaseBased::new(two_dept_profiles(144, 64), 100);
        p.on_join(joiner, 0);
        let l = Ledger::new(10, 3);
        assert_eq!(p.idle_grants(&l, &[DeptId(2)], 0), vec![(DeptId(2), 10)]);
        assert_eq!(p.next_expiry(), Some(100));
        p.on_leave(DeptId(2), 50);
        assert_eq!(p.next_expiry(), None, "departed dept's lease survived");
    }

    #[test]
    fn decisions_conserve_nodes() {
        let l = ledger(7, 20, 3);
        for spec in [
            PolicySpec::Cooperative,
            PolicySpec::StaticPartition,
            PolicySpec::ProportionalShare,
            PolicySpec::Lease { secs: 60 },
            PolicySpec::Tiered,
            PolicySpec::Predictive(PredictiveSpec::default()),
        ] {
            let mut p = spec.build(&two_dept_profiles(144, 64));
            for need in [0, 1, 9, 35, 200] {
                let d = p.on_request(DeptId::WS, need, &l, 5);
                assert_eq!(
                    d.from_free + d.force_total() + d.denied,
                    need,
                    "{}: need {need} not conserved: {d:?}",
                    p.name()
                );
                assert!(d.from_free <= l.free());
                for &(v, n) in &d.force {
                    assert!(n <= l.held(v), "{}: over-forced {v}", p.name());
                }
            }
        }
    }
}
