//! Resource provisioning policies (§II-B) plus baselines for ablation.

use crate::cluster::Ledger;

/// What the policy decided for a WS request of `need` nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProvisionDecision {
    /// Granted straight from the free pool (applied by the RPS).
    pub from_free: u64,
    /// To be forcibly returned by ST (the driver kills jobs, then calls
    /// `complete_force`).
    pub force_from_st: u64,
    /// Demand the policy refused (only the non-cooperative baselines).
    pub denied: u64,
}

/// Provisioning policy selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's cooperative policy: WS has absolute priority; all idle
    /// nodes flow to ST; urgent WS claims force ST returns.
    Cooperative,
    /// The static baseline: hard partition, no flow between departments
    /// (models the two dedicated clusters of the SC configuration).
    StaticPartition { st: u64, ws: u64 },
    /// Ablation: WS may claim only up to a share of the cluster; the rest
    /// is protected for ST (quantifies what WS priority costs ST).
    ProportionalShare { ws_cap: u64 },
}

impl PolicyKind {
    /// Decide a WS request of `need` more nodes given the current ledger.
    pub fn on_ws_request(&self, ledger: &Ledger, need: u64) -> ProvisionDecision {
        match *self {
            PolicyKind::Cooperative => {
                let from_free = need.min(ledger.free());
                let shortfall = need - from_free;
                let force_from_st = shortfall.min(ledger.held(crate::cluster::Owner::St));
                ProvisionDecision {
                    from_free,
                    force_from_st,
                    denied: shortfall - force_from_st,
                }
            }
            PolicyKind::StaticPartition { ws, .. } => {
                let held = ledger.held(crate::cluster::Owner::Ws);
                let allowed = ws.saturating_sub(held);
                let grant = need.min(allowed).min(ledger.free());
                ProvisionDecision { from_free: grant, force_from_st: 0, denied: need - grant }
            }
            PolicyKind::ProportionalShare { ws_cap } => {
                let held = ledger.held(crate::cluster::Owner::Ws);
                let allowed = ws_cap.saturating_sub(held).min(need);
                let from_free = allowed.min(ledger.free());
                let shortfall = allowed - from_free;
                let force_from_st = shortfall.min(ledger.held(crate::cluster::Owner::St));
                ProvisionDecision {
                    from_free,
                    force_from_st,
                    denied: need - from_free - force_from_st,
                }
            }
        }
    }

    /// How much of the free pool goes to ST right now.
    pub fn idle_grant_to_st(&self, ledger: &Ledger) -> u64 {
        match *self {
            // "if there are idle resources … provision all idle to ST"
            PolicyKind::Cooperative | PolicyKind::ProportionalShare { .. } => ledger.free(),
            PolicyKind::StaticPartition { st, .. } => {
                let held = ledger.held(crate::cluster::Owner::St);
                st.saturating_sub(held).min(ledger.free())
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Cooperative => "cooperative",
            PolicyKind::StaticPartition { .. } => "static",
            PolicyKind::ProportionalShare { .. } => "proportional",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Owner;

    fn ledger(free: u64, st: u64, ws: u64) -> Ledger {
        let mut l = Ledger::new(free + st + ws);
        l.transfer(Owner::Free, Owner::St, st).unwrap();
        l.transfer(Owner::Free, Owner::Ws, ws).unwrap();
        l
    }

    #[test]
    fn cooperative_prefers_free_then_forces() {
        let l = ledger(10, 50, 5);
        let d = PolicyKind::Cooperative.on_ws_request(&l, 25);
        assert_eq!(d, ProvisionDecision { from_free: 10, force_from_st: 15, denied: 0 });
    }

    #[test]
    fn cooperative_denies_only_when_cluster_exhausted() {
        let l = ledger(0, 10, 5);
        let d = PolicyKind::Cooperative.on_ws_request(&l, 25);
        assert_eq!(d.force_from_st, 10);
        assert_eq!(d.denied, 15);
    }

    #[test]
    fn cooperative_gives_all_idle_to_st() {
        let l = ledger(42, 0, 0);
        assert_eq!(PolicyKind::Cooperative.idle_grant_to_st(&l), 42);
    }

    #[test]
    fn static_partition_caps_both_sides() {
        let p = PolicyKind::StaticPartition { st: 144, ws: 64 };
        let l = ledger(144 + 14, 0, 50); // ws holds 50 of its 64
        let d = p.on_ws_request(&l, 30);
        assert_eq!(d.from_free, 14);
        assert_eq!(d.force_from_st, 0);
        assert_eq!(d.denied, 16);
        // ST fills only to its partition
        assert_eq!(p.idle_grant_to_st(&ledger(200, 100, 0)), 44);
    }

    #[test]
    fn proportional_share_caps_ws() {
        let p = PolicyKind::ProportionalShare { ws_cap: 40 };
        let l = ledger(0, 100, 30);
        let d = p.on_ws_request(&l, 30);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force_from_st, 10); // only up to the 40-node cap
        assert_eq!(d.denied, 20);
    }
}
