//! Per-tier policy mixes: one organization, different provisioning
//! contracts per priority tier.
//!
//! The follow-up studies (arXiv:1006.1401 §IV, arXiv:1004.1276) observe
//! that a large organization rarely runs *one* provisioning contract:
//! premium departments keep cooperative priority while bulk batch tiers
//! accept lease-style resizing. [`MixedPolicy`] composes the base
//! [`ProvisionPolicy`] implementations along the tier axis: every
//! department is routed — by its profile's `tier` — to one sub-policy,
//! and the combinator merges their decisions while preserving the node
//! conservation contract (`from_free + force_total + denied == need`,
//! grants never exceed the free pool; property-tested alongside the base
//! policies in `tests/properties.rs`).
//!
//! Routing rules:
//! * `on_request` / `on_release` / `on_force` / `renewed` go to the
//!   sub-policy owning the department's tier.
//! * `idle_grants` partitions the eligible departments by owning
//!   sub-policy and consults the partitions in **priority order** — the
//!   sub-policy owning the highest-priority (lowest-tier) eligible
//!   department goes first — so premium tiers see idle capacity before
//!   lower, typically leased, tiers; the combined grant list is clamped
//!   so the total never exceeds the free pool. A clamped lease-based
//!   sub-policy may book slightly more than was actually granted; the
//!   driver already treats lease books as advisory (reclaims are capped
//!   by the department's idle nodes, renewals by its busy nodes), so
//!   stale entries expire harmlessly.
//! * `expired` / `next_expiry` merge across every sub-policy.

use std::collections::BTreeMap;

use crate::cluster::{DeptId, Ledger};
use crate::sim::SimTime;

use super::policy::{DeptProfile, PolicySpec, ProvisionDecision, ProvisionPolicy};

/// One rule of a mixed policy: departments on `tier` follow `spec`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierRule {
    pub tier: u8,
    pub spec: PolicySpec,
}

/// Declarative policy selection covering both the base policies and the
/// per-tier mixes — the parsed form of the `[policy]` config section
/// (`kind = "mixed"` adds `[[policy.tier]]` rules).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PolicyChoice {
    /// One base policy for every department.
    Base(PolicySpec),
    /// Per-tier rules over a default base policy.
    Mixed {
        default: PolicySpec,
        rules: Vec<TierRule>,
    },
}

impl PolicyChoice {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyChoice::Base(spec) => spec.name(),
            PolicyChoice::Mixed { .. } => "mixed",
        }
    }

    /// Instantiate over the given department profiles.
    pub fn build(&self, depts: &[DeptProfile]) -> Box<dyn ProvisionPolicy> {
        match self {
            PolicyChoice::Base(spec) => spec.build(depts),
            PolicyChoice::Mixed { default, rules } => {
                Box::new(MixedPolicy::new(depts.to_vec(), rules.clone(), *default))
            }
        }
    }

    /// Overwrite every predictive spec this choice carries with the
    /// config-level forecast knobs (`PolicySpec::parse` only ever yields
    /// the default spec — the knobs live in `ExperimentConfig`).
    pub fn patch_predictive(&mut self, spec: crate::provision::PredictiveSpec) {
        let patch = |s: &mut PolicySpec| {
            if let PolicySpec::Predictive(p) = s {
                *p = spec;
            }
        };
        match self {
            PolicyChoice::Base(s) => patch(s),
            PolicyChoice::Mixed { default, rules } => {
                patch(default);
                for rule in rules {
                    patch(&mut rule.spec);
                }
            }
        }
    }

    /// Every lease term this choice carries (validation helper).
    pub fn lease_terms(&self) -> Vec<u64> {
        let term = |spec: &PolicySpec| match spec {
            PolicySpec::Lease { secs } => Some(*secs),
            _ => None,
        };
        match self {
            PolicyChoice::Base(spec) => term(spec).into_iter().collect(),
            PolicyChoice::Mixed { default, rules } => term(default)
                .into_iter()
                .chain(rules.iter().filter_map(|r| term(&r.spec)))
                .collect(),
        }
    }
}

/// The per-tier combinator. Each sub-policy is built over the *full*
/// profile roster (so a cooperative service tier may still force-reclaim
/// from any batch department, whatever contract the victim's tier runs);
/// only the *routing* of requests, releases, and bookkeeping is per tier.
#[derive(Debug)]
pub struct MixedPolicy {
    depts: Vec<DeptProfile>,
    /// Sub-policies, rule order first, the default last.
    subs: Vec<Box<dyn ProvisionPolicy>>,
    /// tier → index into `subs`; unlisted tiers use the default (last).
    routes: BTreeMap<u8, usize>,
}

impl MixedPolicy {
    pub fn new(depts: Vec<DeptProfile>, rules: Vec<TierRule>, default: PolicySpec) -> Self {
        let mut subs: Vec<Box<dyn ProvisionPolicy>> = Vec::with_capacity(rules.len() + 1);
        let mut routes = BTreeMap::new();
        for rule in &rules {
            // later rules override earlier ones for the same tier
            routes.insert(rule.tier, subs.len());
            subs.push(rule.spec.build(&depts));
        }
        subs.push(default.build(&depts));
        Self { depts, subs, routes }
    }

    /// Which sub-policy owns `dept` (default for unknown departments).
    fn route(&self, dept: DeptId) -> usize {
        let default = self.subs.len() - 1;
        self.depts
            .iter()
            .find(|p| p.id == dept)
            .and_then(|p| self.routes.get(&p.tier).copied())
            .unwrap_or(default)
    }
}

impl ProvisionPolicy for MixedPolicy {
    fn name(&self) -> &str {
        "mixed"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        now: SimTime,
    ) -> ProvisionDecision {
        let sub = self.route(dept);
        self.subs[sub].on_request(dept, need, ledger, now)
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        let mut remaining = ledger.free();
        let mut out = Vec::new();
        let owners: Vec<usize> = eligible.iter().map(|&d| self.route(d)).collect();
        // visit each sub-policy's partition in priority order: the one
        // owning the highest-priority (lowest-tier) eligible department
        // first, ties to the earlier rule — premium tiers must not be
        // starved by a lower, leased tier draining the pool first
        let tier_of = |d: DeptId| {
            self.depts.iter().find(|p| p.id == d).map(|p| p.tier).unwrap_or(u8::MAX)
        };
        let mut order: Vec<(u8, usize)> = Vec::new();
        for (&d, &o) in eligible.iter().zip(&owners) {
            let t = tier_of(d);
            match order.iter_mut().find(|&&mut (_, sub)| sub == o) {
                Some(entry) => entry.0 = entry.0.min(t),
                None => order.push((t, o)),
            }
        }
        order.sort_by_key(|&(t, sub)| (t, sub));
        for (_, sub) in order {
            if remaining == 0 {
                break;
            }
            let mine: Vec<DeptId> = eligible
                .iter()
                .zip(&owners)
                .filter(|&(_, &o)| o == sub)
                .map(|(&d, _)| d)
                .collect();
            for (d, n) in self.subs[sub].idle_grants(ledger, &mine, now) {
                let n = n.min(remaining);
                if n > 0 {
                    remaining -= n;
                    out.push((d, n));
                }
            }
        }
        out
    }

    fn on_release(&mut self, dept: DeptId, n: u64, now: SimTime) {
        let sub = self.route(dept);
        self.subs[sub].on_release(dept, n, now);
    }

    fn on_force(&mut self, victim: DeptId, n: u64, now: SimTime) {
        let sub = self.route(victim);
        self.subs[sub].on_force(victim, n, now);
    }

    fn expired(&mut self, now: SimTime) -> Vec<(DeptId, u64)> {
        let mut total: BTreeMap<DeptId, u64> = BTreeMap::new();
        for sub in &mut self.subs {
            for (d, n) in sub.expired(now) {
                *total.entry(d).or_insert(0) += n;
            }
        }
        total.into_iter().collect()
    }

    fn renewed(&mut self, dept: DeptId, n: u64, now: SimTime) {
        let sub = self.route(dept);
        self.subs[sub].renewed(dept, n, now);
    }

    fn next_expiry(&self) -> Option<SimTime> {
        self.subs.iter().filter_map(|s| s.next_expiry()).min()
    }

    fn on_join(&mut self, profile: DeptProfile, now: SimTime) {
        // every sub-policy was built over the full roster, so every one
        // must learn about the joiner (whatever tier routes its requests)
        super::policy::upsert_profile(&mut self.depts, profile);
        for sub in &mut self.subs {
            sub.on_join(profile, now);
        }
    }

    fn on_leave(&mut self, dept: DeptId, now: SimTime) {
        super::policy::remove_profile(&mut self.depts, dept);
        for sub in &mut self.subs {
            sub.on_leave(dept, now);
        }
    }

    fn on_crash(&mut self, holder: Option<DeptId>, n: u64, now: SimTime) {
        // the holder's owning tier voids its own lease books (like
        // on_force); a free-pool crash has no holder to route
        if let Some(dept) = holder {
            let sub = self.route(dept);
            self.subs[sub].on_crash(holder, n, now);
        }
    }

    fn on_recover(&mut self, n: u64, now: SimTime) {
        for sub in &mut self.subs {
            sub.on_recover(n, now);
        }
    }

    fn observe(&mut self, dept: DeptId, util: f64, demand: u64, now: SimTime) {
        // demand samples reach the owning sub-policy only: a predictive
        // tier must not train on (or reserve against) departments whose
        // requests another contract routes
        let sub = self.route(dept);
        self.subs[sub].observe(dept, util, demand, now);
    }

    fn forecast_stats(&self) -> Option<crate::forecast::ForecastStats> {
        let mut merged: Option<crate::forecast::ForecastStats> = None;
        for sub in &self.subs {
            if let Some(s) = sub.forecast_stats() {
                merged.get_or_insert_with(Default::default).merge(&s);
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeptKind;

    /// service tier 0 + batch tiers 1 and 2.
    fn three_tier_depts() -> Vec<DeptProfile> {
        vec![
            DeptProfile { id: DeptId(0), kind: DeptKind::Service, tier: 0, quota: 64 },
            DeptProfile { id: DeptId(1), kind: DeptKind::Batch, tier: 1, quota: 100 },
            DeptProfile { id: DeptId(2), kind: DeptKind::Batch, tier: 2, quota: 100 },
        ]
    }

    fn mixed_lease_bottom() -> MixedPolicy {
        MixedPolicy::new(
            three_tier_depts(),
            vec![TierRule { tier: 2, spec: PolicySpec::Lease { secs: 100 } }],
            PolicySpec::Cooperative,
        )
    }

    #[test]
    fn routes_by_tier_and_defaults() {
        let p = mixed_lease_bottom();
        assert_eq!(p.route(DeptId(2)), 0, "tier-2 rule");
        assert_eq!(p.route(DeptId(0)), 1, "tier 0 falls to the default");
        assert_eq!(p.route(DeptId(1)), 1);
        assert_eq!(p.route(DeptId(9)), 1, "unknown departments use the default");
        assert_eq!(p.name(), "mixed");
    }

    #[test]
    fn leased_tier_books_grants_and_cooperative_tier_does_not() {
        let mut p = mixed_lease_bottom();
        let mut l = Ledger::new(40, 3);
        l.grant(DeptId(0), 10).unwrap(); // 30 free
        // only the tier-2 department is eligible: its grant carries a lease
        let grants = p.idle_grants(&l, &[DeptId(2)], 0);
        assert_eq!(grants, vec![(DeptId(2), 30)]);
        assert_eq!(p.next_expiry(), Some(100));
        assert_eq!(p.expired(100), vec![(DeptId(2), 30)]);
        // the tier-1 (cooperative) department books nothing
        let grants = p.idle_grants(&l, &[DeptId(1)], 0);
        assert_eq!(grants, vec![(DeptId(1), 30)]);
        assert_eq!(p.next_expiry(), None);
    }

    #[test]
    fn combined_idle_grants_never_exceed_free_pool_and_favor_premium_tiers() {
        let mut p = mixed_lease_bottom();
        let mut l = Ledger::new(20, 3);
        l.grant(DeptId(0), 5).unwrap(); // 15 free
        // both batch departments eligible, owned by different sub-policies:
        // each sub would grant the whole pool to its subset; the combinator
        // must clamp the union to 15 — and the premium (tier-1, default
        // cooperative) department is served before the leased bottom tier
        let grants = p.idle_grants(&l, &[DeptId(1), DeptId(2)], 0);
        let total: u64 = grants.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 15, "{grants:?}");
        assert_eq!(grants, vec![(DeptId(1), 15)], "premium tier must be served first");
        assert_eq!(p.next_expiry(), None, "nothing reached the leased tier");
    }

    #[test]
    fn requests_follow_the_owning_tier_contract() {
        let mut p = mixed_lease_bottom();
        let mut l = Ledger::new(30, 3);
        l.grant(DeptId(1), 20).unwrap();
        l.grant(DeptId(2), 10).unwrap();
        // the service department routes to cooperative: free pool (0) then
        // force from the batch departments, largest holdings first
        let d = p.on_request(DeptId(0), 25, &l, 5);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force, vec![(DeptId(1), 20), (DeptId(2), 5)]);
        assert_eq!(d.denied, 0);
        // forcing the leased tier drops its book entries
        p.idle_grants(&Ledger::new(8, 3), &[DeptId(2)], 10);
        p.on_force(DeptId(2), 8, 20);
        assert_eq!(p.next_expiry(), None, "stale lease survived the force");
    }

    #[test]
    fn renewals_route_to_the_leasing_sub_policy() {
        let mut p = mixed_lease_bottom();
        let l = Ledger::new(12, 3);
        p.idle_grants(&l, &[DeptId(2)], 0); // leased until 100
        assert_eq!(p.expired(100), vec![(DeptId(2), 12)]);
        p.renewed(DeptId(2), 7, 100);
        assert_eq!(p.next_expiry(), Some(200));
        assert_eq!(p.expired(200), vec![(DeptId(2), 7)]);
    }

    #[test]
    fn join_and_leave_reach_every_sub_policy() {
        let mut p = mixed_lease_bottom();
        // a tier-2 batch joiner routes to the leased sub-policy
        let joiner = DeptProfile { id: DeptId(3), kind: DeptKind::Batch, tier: 2, quota: 50 };
        p.on_join(joiner, 0);
        assert_eq!(p.route(DeptId(3)), 0, "joiner must route to its tier's rule");
        let l = Ledger::new(12, 4);
        assert_eq!(p.idle_grants(&l, &[DeptId(3)], 0), vec![(DeptId(3), 12)]);
        assert_eq!(p.next_expiry(), Some(100), "joiner's grant must be leased");
        // leaving drops the profile and the lease book entries everywhere
        p.on_leave(DeptId(3), 50);
        assert_eq!(p.next_expiry(), None);
        assert_eq!(p.route(DeptId(3)), 1, "departed dept falls to the default route");
    }

    #[test]
    fn crashes_void_the_owning_tier_lease_book() {
        let mut p = mixed_lease_bottom();
        let l = Ledger::new(12, 3);
        p.idle_grants(&l, &[DeptId(2)], 0); // leased until 100
        assert_eq!(p.next_expiry(), Some(100));
        // a crash in the leased holder's pool voids its booked nodes
        p.on_crash(Some(DeptId(2)), 12, 50);
        assert_eq!(p.next_expiry(), None, "crash must void the lease book");
        // free-pool crashes and recoveries are no-ops on every sub-policy
        p.on_crash(None, 1, 60);
        p.on_recover(1, 70);
        assert_eq!(p.next_expiry(), None);
    }

    #[test]
    fn predictive_tier_observes_and_reports_through_the_mix() {
        use crate::provision::PredictiveSpec;
        let spec = PredictiveSpec { window: 4, horizon_secs: 120, headroom_tenths: 0 };
        let mut p = MixedPolicy::new(
            three_tier_depts(),
            vec![TierRule { tier: 0, spec: PolicySpec::Predictive(spec) }],
            PolicySpec::Cooperative,
        );
        assert!(p.forecast_stats().is_some(), "predictive sub must surface stats");
        for i in 0..4u64 {
            p.observe(DeptId(0), 0.7, 10 + i, i * 60);
        }
        assert_eq!(p.forecast_stats().unwrap().samples, 4);
        // samples for a cooperative-routed department never reach (or
        // train) the predictive tier
        p.observe(DeptId(1), 0.5, 3, 300);
        assert_eq!(p.forecast_stats().unwrap().samples, 4);
    }

    #[test]
    fn choice_builds_base_and_mixed() {
        let depts = three_tier_depts();
        let base = PolicyChoice::Base(PolicySpec::Tiered);
        assert_eq!(base.name(), "tiered");
        assert_eq!(base.build(&depts).name(), "tiered");
        let mixed = PolicyChoice::Mixed {
            default: PolicySpec::Cooperative,
            rules: vec![TierRule { tier: 2, spec: PolicySpec::Lease { secs: 60 } }],
        };
        assert_eq!(mixed.name(), "mixed");
        assert_eq!(mixed.build(&depts).name(), "mixed");
        assert_eq!(mixed.lease_terms(), vec![60]);
        assert!(base.lease_terms().is_empty());
    }
}
