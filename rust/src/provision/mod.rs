//! The Resource Provision Service (RPS) — the common service framework's
//! proxy for the whole organization (§II-B): it owns the ledger and
//! decides when to provision how many nodes to which CMS, under a
//! pluggable [`ProvisionPolicy`]. Where the paper's RPS arbitrates between
//! exactly two departments, this one serves N (arXiv:1006.1401): every
//! request, release, idle grant, and lease expiration is keyed by
//! [`DeptId`].

pub mod mixed;
pub mod policy;
pub mod predictive;

use crate::cluster::{DeptId, Ledger};
use crate::forecast::ForecastStats;
use crate::sim::SimTime;

pub use self::mixed::{MixedPolicy, PolicyChoice, TierRule};
pub use self::policy::{
    two_dept_profiles, Cooperative, DeptProfile, LeaseBased, PolicySpec, ProportionalShare,
    ProvisionDecision, ProvisionPolicy, StaticPartition, TieredCooperative,
};
pub use self::predictive::{Predictive, PredictiveSpec};

/// The RPS: ledger + policy.
#[derive(Debug)]
pub struct Rps {
    ledger: Ledger,
    policy: Box<dyn ProvisionPolicy>,
    /// Forced-return events issued (metrics).
    pub force_returns: u64,
    /// Nodes moved by forced returns (metrics).
    pub forced_nodes: u64,
}

impl Rps {
    pub fn new(total_nodes: u64, num_depts: usize, policy: Box<dyn ProvisionPolicy>) -> Self {
        Self {
            ledger: Ledger::new(total_nodes, num_depts),
            policy,
            force_returns: 0,
            forced_nodes: 0,
        }
    }

    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    pub fn policy_name(&self) -> &str {
        self.policy.name()
    }

    /// Department `dept` claims `need` more nodes (urgent). The policy
    /// decides how much comes from the free pool and how much must be
    /// forced out of which departments; the free-pool part is applied
    /// here, the forced part after the driver performs the victim-side
    /// kills and calls [`Rps::complete_force`].
    pub fn request(&mut self, dept: DeptId, need: u64, now: SimTime) -> ProvisionDecision {
        let d = self.policy.on_request(dept, need, &self.ledger, now);
        if d.from_free > 0 {
            self.ledger
                .grant(dept, d.from_free)
                // phoenix-lint: allow(panic_path): conservation invariant — the property suite proves every built-in respects from_free <= free()
                .expect("policy over-granted free nodes");
        }
        if !d.force.is_empty() {
            self.force_returns += 1;
            self.forced_nodes += d.force_total();
        }
        d
    }

    /// Finish a forced return after `from` released the nodes. Lease
    /// policies drop the forced nodes from their lease book here.
    pub fn complete_force(&mut self, from: DeptId, to: DeptId, n: u64, now: SimTime) {
        self.ledger
            .transfer(from, to, n)
            // phoenix-lint: allow(panic_path): conservation invariant — forced amounts are capped by the victim's holdings
            .expect("forced transfer exceeded the victim's holding");
        self.policy.on_force(from, n, now);
    }

    /// Department `dept` released `n` idle nodes.
    pub fn release(&mut self, dept: DeptId, n: u64, now: SimTime) {
        self.ledger
            .release(dept, n)
            // phoenix-lint: allow(panic_path): conservation invariant — drivers release only nodes the CMS holds
            .expect("department released more than it held");
        self.policy.on_release(dept, n, now);
    }

    /// Provision idle resources per the policy ("if there are idle
    /// resources, provision all of them to ST Server", generalized to the
    /// eligible batch departments). Applies and returns the grants.
    pub fn provision_idle(
        &mut self,
        eligible: &[DeptId],
        now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        let grants = self.policy.idle_grants(&self.ledger, eligible, now);
        for &(d, n) in &grants {
            // phoenix-lint: allow(panic_path): conservation invariant — idle_grants must sum to <= free()
            self.ledger.grant(d, n).expect("idle grant exceeded free pool");
        }
        grants
    }

    /// Grant up to `n` nodes straight from the free pool to `dept`
    /// (cluster-boot path). Returns the amount actually granted.
    pub fn bootstrap_grant(&mut self, dept: DeptId, n: u64) -> u64 {
        let grant = n.min(self.ledger.free());
        if grant > 0 {
            // phoenix-lint: allow(panic_path): grant was min()-ed against free() on the line above
            self.ledger.grant(dept, grant).expect("bootstrap grant overdraw");
        }
        grant
    }

    /// Leases that expired by `now`: the driver reclaims what it can (idle
    /// nodes) via [`Rps::lease_return`].
    pub fn lease_expirations(&mut self, now: SimTime) -> Vec<(DeptId, u64)> {
        self.policy.expired(now)
    }

    /// Settle one expired lease: `returned` nodes go back to the free
    /// pool, `renewed` nodes stay with the department for another term.
    pub fn lease_return(&mut self, dept: DeptId, returned: u64, renewed: u64, now: SimTime) {
        if returned > 0 {
            self.ledger
                .release(dept, returned)
                // phoenix-lint: allow(panic_path): the driver caps lease returns by the department's idle holding
                .expect("lease returned more than the department held");
        }
        if renewed > 0 {
            self.policy.renewed(dept, renewed, now);
        }
    }

    /// Earliest pending lease expiry, if the policy leases at all.
    pub fn next_expiry(&self) -> Option<SimTime> {
        self.policy.next_expiry()
    }

    /// Feed one per-department demand sample to the policy (no-op for
    /// reactive policies; the Predictive policy trains its tracker here).
    pub fn observe(&mut self, dept: DeptId, util: f64, demand: u64, now: SimTime) {
        self.policy.observe(dept, util, demand, now);
    }

    /// Forecast-quality counters, when the policy forecasts at all.
    pub fn forecast_stats(&self) -> Option<ForecastStats> {
        self.policy.forecast_stats()
    }

    /// A department joins the shared cluster at runtime (dynamic
    /// affiliation, arXiv:1003.0958): the ledger grows one zero-holding
    /// slot and the policy starts tracking the profile. `profile.id` must
    /// be the next dense id — departments join in id order, exactly as
    /// the serve loop assigns them.
    pub fn join(&mut self, profile: DeptProfile, now: SimTime) -> DeptId {
        let id = self.ledger.add_dept();
        assert_eq!(
            id, profile.id,
            "join ids must be dense and in arrival order (ledger assigned {id})"
        );
        self.policy.on_join(profile, now);
        id
    }

    /// A department leaves the cluster: whatever it still holds returns to
    /// the free pool (the driver has already reclaimed the nodes from its
    /// CMS) and the policy forgets the profile. Returns the reclaimed
    /// node count.
    pub fn leave(&mut self, dept: DeptId, now: SimTime) -> u64 {
        let held = self.ledger.held(dept);
        if held > 0 {
            self.ledger
                .release(dept, held)
                // phoenix-lint: allow(panic_path): held was read from the same ledger two lines up
                .expect("leave releases exactly what the department held");
        }
        self.policy.on_leave(dept, now);
        held
    }

    /// `n` nodes crashed: out of `holder`'s pool (the driver has already
    /// killed the victim's work on them) or out of the free pool (`None`).
    /// They move to the ledger's `down` pool and the policy voids any
    /// lease books covering them.
    pub fn crash(&mut self, holder: Option<DeptId>, n: u64, now: SimTime) {
        match holder {
            Some(dept) => self
                .ledger
                .crash_held(dept, n)
                // phoenix-lint: allow(panic_path): fault driver caps crashes by the holder's live nodes
                .expect("crash exceeded the holder's nodes"),
            // phoenix-lint: allow(panic_path): fault driver caps crashes by the free pool
            None => self.ledger.crash_free(n).expect("crash exceeded the free pool"),
        }
        self.policy.on_crash(holder, n, now);
    }

    /// `n` crashed nodes finished repair: they re-enter the free pool and
    /// the policy is told so the driver's next re-provisioning pass can
    /// hand them out.
    pub fn recover(&mut self, n: u64, now: SimTime) {
        // phoenix-lint: allow(panic_path): recoveries are paired 1:1 with earlier crashes by the schedule
        self.ledger.recover(n).expect("recovered more nodes than were down");
        self.policy.on_recover(n, now);
    }

    /// Crash up to `n` nodes using the standard victim rule: the free pool
    /// first, then the holder with the largest holding (ties to the lower
    /// id). Returns the per-victim breakdown (`None` = free pool) so the
    /// driver can kill the victims' work. Crashes fewer than `n` only if
    /// the whole cluster is already down.
    pub fn crash_anywhere(&mut self, n: u64, now: SimTime) -> Vec<(Option<DeptId>, u64)> {
        let mut out = Vec::new();
        let mut left = n;
        let from_free = left.min(self.ledger.free());
        if from_free > 0 {
            self.crash(None, from_free, now);
            out.push((None, from_free));
            left -= from_free;
        }
        while left > 0 {
            let (_, held) = self.ledger.snapshot();
            let victim = held
                .iter()
                .enumerate()
                .filter(|&(_, &h)| h > 0)
                .max_by_key(|&(i, &h)| (h, std::cmp::Reverse(i)))
                .map(|(i, &h)| (DeptId(i as u16), h));
            let Some((dept, held)) = victim else {
                break; // whole cluster already down
            };
            let take = left.min(held);
            self.crash(Some(dept), take, now);
            out.push((Some(dept), take));
            left -= take;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeptKind;

    fn coop(total: u64) -> Rps {
        let profiles = two_dept_profiles(144, 64);
        Rps::new(total, 2, PolicySpec::Cooperative.build(&profiles))
    }

    #[test]
    fn bootstrap_then_idle_grants_everything() {
        let mut rps = coop(160);
        let ws = rps.bootstrap_grant(DeptId::WS, 1);
        assert_eq!(ws, 1);
        let grants = rps.provision_idle(&[DeptId::ST], 0);
        assert_eq!(grants, vec![(DeptId::ST, 159)]);
        assert_eq!(rps.ledger().free(), 0);
    }

    #[test]
    fn request_from_free_then_force() {
        let mut rps = coop(100);
        rps.provision_idle(&[DeptId::ST], 0); // all 100 to ST
        let d = rps.request(DeptId::WS, 30, 0);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force, vec![(DeptId::ST, 30)]);
        rps.complete_force(DeptId::ST, DeptId::WS, 30, 0);
        assert_eq!(rps.ledger().held(DeptId::WS), 30);
        assert_eq!(rps.force_returns, 1);
        assert_eq!(rps.forced_nodes, 30);
    }

    #[test]
    fn release_then_idle_back_to_batch() {
        let mut rps = coop(100);
        rps.bootstrap_grant(DeptId::WS, 40);
        rps.provision_idle(&[DeptId::ST], 0);
        rps.release(DeptId::WS, 10, 50);
        assert_eq!(rps.ledger().free(), 10);
        let grants = rps.provision_idle(&[DeptId::ST], 50);
        assert_eq!(grants, vec![(DeptId::ST, 10)]);
        assert_eq!(rps.ledger().free(), 0);
    }

    #[test]
    fn static_policy_never_forces() {
        let profiles = two_dept_profiles(144, 64);
        let mut rps = Rps::new(208, 2, PolicySpec::StaticPartition.build(&profiles));
        rps.bootstrap_grant(DeptId::WS, 64);
        rps.provision_idle(&[DeptId::ST], 0);
        assert_eq!(rps.ledger().held(DeptId::ST), 144);
        // WS asks beyond its partition: nothing from free, nothing forced
        let d = rps.request(DeptId::WS, 10, 0);
        assert_eq!(d.from_free, 0);
        assert!(d.force.is_empty());
        assert!(d.denied > 0);
    }

    #[test]
    fn lease_cycle_through_the_rps() {
        let profiles = two_dept_profiles(144, 64);
        let mut rps = Rps::new(50, 2, PolicySpec::Lease { secs: 100 }.build(&profiles));
        rps.provision_idle(&[DeptId::ST], 0);
        assert_eq!(rps.ledger().held(DeptId::ST), 50);
        assert_eq!(rps.next_expiry(), Some(100));
        let exp = rps.lease_expirations(100);
        assert_eq!(exp, vec![(DeptId::ST, 50)]);
        // driver found 20 idle: they return; 30 busy renew
        rps.lease_return(DeptId::ST, 20, 30, 100);
        assert_eq!(rps.ledger().free(), 20);
        assert_eq!(rps.ledger().held(DeptId::ST), 30);
        assert_eq!(rps.next_expiry(), Some(200));
    }

    #[test]
    fn join_then_leave_round_trips_through_the_rps() {
        let mut rps = coop(100);
        rps.provision_idle(&[DeptId::ST], 0); // all 100 to ST
        let profile =
            DeptProfile { id: DeptId(2), kind: DeptKind::Batch, tier: 1, quota: 40 };
        assert_eq!(rps.join(profile, 10), DeptId(2));
        assert_eq!(rps.ledger().num_depts(), 3);
        // the joiner can now be granted and forced like any other dept
        let d = rps.request(DeptId::WS, 10, 20);
        let forced_from_st = d.force.iter().any(|&(v, _)| v == DeptId::ST);
        assert!(forced_from_st, "{d:?}");
        for &(v, n) in &d.force {
            rps.complete_force(v, DeptId::WS, n, 20);
        }
        rps.release(DeptId::WS, 10, 30);
        let grants = rps.provision_idle(&[DeptId(2)], 30);
        assert_eq!(grants, vec![(DeptId(2), 10)]);
        // leave: holdings flow back to the free pool, profile forgotten
        assert_eq!(rps.leave(DeptId(2), 40), 10);
        assert_eq!(rps.ledger().held(DeptId(2)), 0);
        assert_eq!(rps.ledger().free(), 10);
        let (free, held) = rps.ledger().snapshot();
        assert_eq!(free + held.iter().sum::<u64>(), 100);
    }

    #[test]
    fn crash_and_recover_round_trip_through_the_rps() {
        let mut rps = coop(100);
        rps.bootstrap_grant(DeptId::WS, 30);
        // 70 free: a 10-node crash comes out of the free pool first
        let victims = rps.crash_anywhere(10, 5);
        assert_eq!(victims, vec![(None, 10)]);
        assert_eq!(rps.ledger().down(), 10);
        assert_eq!(rps.ledger().free(), 60);
        rps.provision_idle(&[DeptId::ST], 5); // remaining 60 to ST
        // nothing free now: the largest holder (ST, 60) is the victim
        let victims = rps.crash_anywhere(15, 10);
        assert_eq!(victims, vec![(Some(DeptId::ST), 15)]);
        assert_eq!(rps.ledger().down(), 25);
        assert_eq!(rps.ledger().held(DeptId::ST), 45);
        // recovery returns the nodes to the free pool
        rps.recover(25, 20);
        assert_eq!(rps.ledger().down(), 0);
        assert_eq!(rps.ledger().free(), 25);
        let (free, held) = rps.ledger().snapshot();
        assert_eq!(free + held.iter().sum::<u64>(), 100);
    }

    #[test]
    fn crash_voids_lease_books() {
        let profiles = two_dept_profiles(144, 64);
        let mut rps = Rps::new(50, 2, PolicySpec::Lease { secs: 100 }.build(&profiles));
        rps.provision_idle(&[DeptId::ST], 0); // 50 leased until 100
        assert_eq!(rps.next_expiry(), Some(100));
        rps.crash(Some(DeptId::ST), 50, 10);
        assert_eq!(rps.next_expiry(), None, "crash must void the lease book");
        assert_eq!(rps.ledger().held(DeptId::ST), 0);
        assert_eq!(rps.ledger().down(), 50);
    }

    #[test]
    fn crash_anywhere_stops_at_an_empty_cluster() {
        let mut rps = coop(10);
        let victims = rps.crash_anywhere(25, 0);
        assert_eq!(victims, vec![(None, 10)]);
        assert_eq!(rps.ledger().down(), 10);
        assert_eq!(rps.ledger().free(), 0);
    }

    #[test]
    fn many_departments_route_independently() {
        // 3 batch + 2 service departments on one 300-node cluster
        let profiles: Vec<DeptProfile> = (0..5u16)
            .map(|i| DeptProfile {
                id: DeptId(i),
                kind: if i < 3 { DeptKind::Batch } else { DeptKind::Service },
                tier: u8::from(i >= 3),
                quota: 60,
            })
            .collect();
        let mut rps = Rps::new(300, 5, PolicySpec::Cooperative.build(&profiles));
        let batch: Vec<DeptId> = (0..3).map(DeptId).collect();
        let grants = rps.provision_idle(&batch, 0);
        assert_eq!(grants.iter().map(|&(_, n)| n).sum::<u64>(), 300);
        // a service dept claims 50: forced off the largest batch holder
        let d = rps.request(DeptId(4), 50, 10);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force_total(), 50);
        for &(victim, n) in &d.force {
            rps.complete_force(victim, DeptId(4), n, 10);
        }
        assert_eq!(rps.ledger().held(DeptId(4)), 50);
        let (free, held) = rps.ledger().snapshot();
        assert_eq!(free + held.iter().sum::<u64>(), 300);
    }
}
