//! The Resource Provision Service (RPS) — the common service framework's
//! proxy for the whole organization (§II-B): it owns the ledger and decides
//! when to provision how many nodes to which CMS, under a pluggable policy.

pub mod policy;

use crate::cluster::{Ledger, Owner};

pub use self::policy::{PolicyKind, ProvisionDecision};

/// The RPS: ledger + policy.
#[derive(Debug)]
pub struct Rps {
    ledger: Ledger,
    policy: PolicyKind,
    /// Forced-return events issued (metrics).
    pub force_returns: u64,
    /// Nodes moved by forced returns (metrics).
    pub forced_nodes: u64,
}

impl Rps {
    pub fn new(total_nodes: u64, policy: PolicyKind) -> Self {
        Self { ledger: Ledger::new(total_nodes), policy, force_returns: 0, forced_nodes: 0 }
    }

    pub fn ledger(&self) -> &Ledger {
        &self.ledger
    }

    pub fn policy(&self) -> PolicyKind {
        self.policy
    }

    /// WS claims `need` more nodes (urgent). The policy decides how much
    /// comes from the free pool and how much must be forced out of ST; the
    /// driver performs the ST-side kills then calls [`Rps::complete_force`].
    pub fn ws_request(&mut self, need: u64) -> ProvisionDecision {
        let d = self.policy.on_ws_request(&self.ledger, need);
        if d.from_free > 0 {
            self.ledger
                .transfer(Owner::Free, Owner::Ws, d.from_free)
                .expect("policy over-granted free nodes");
        }
        if d.force_from_st > 0 {
            self.force_returns += 1;
            self.forced_nodes += d.force_from_st;
        }
        d
    }

    /// Finish a forced return after ST released the nodes.
    pub fn complete_force(&mut self, n: u64) {
        self.ledger
            .transfer(Owner::St, Owner::Ws, n)
            .expect("forced transfer exceeded ST holding");
    }

    /// WS released `n` idle nodes.
    pub fn ws_release(&mut self, n: u64) {
        self.ledger
            .transfer(Owner::Ws, Owner::Free, n)
            .expect("WS released more than it held");
    }

    /// Provision idle resources to ST per the policy ("if there are idle
    /// resources, provision all of them to ST Server"). Returns the grant.
    pub fn provision_idle_to_st(&mut self) -> u64 {
        let grant = self.policy.idle_grant_to_st(&self.ledger);
        if grant > 0 {
            self.ledger
                .transfer(Owner::Free, Owner::St, grant)
                .expect("idle grant exceeded free pool");
        }
        grant
    }

    /// Initial split at cluster boot.
    pub fn bootstrap(&mut self, ws_initial: u64) -> (u64, u64) {
        let ws = ws_initial.min(self.ledger.free());
        if ws > 0 {
            self.ledger.transfer(Owner::Free, Owner::Ws, ws).unwrap();
        }
        let st = self.provision_idle_to_st();
        (ws, st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_grants_everything() {
        let mut rps = Rps::new(160, PolicyKind::Cooperative);
        let (ws, st) = rps.bootstrap(1);
        assert_eq!(ws, 1);
        assert_eq!(st, 159);
        assert_eq!(rps.ledger().free(), 0);
    }

    #[test]
    fn ws_request_from_free_then_force() {
        let mut rps = Rps::new(100, PolicyKind::Cooperative);
        rps.bootstrap(0); // all 100 to ST
        let d = rps.ws_request(30);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force_from_st, 30);
        rps.complete_force(30);
        assert_eq!(rps.ledger().held(crate::cluster::Owner::Ws), 30);
        assert_eq!(rps.force_returns, 1);
        assert_eq!(rps.forced_nodes, 30);
    }

    #[test]
    fn ws_release_then_idle_to_st() {
        let mut rps = Rps::new(100, PolicyKind::Cooperative);
        rps.bootstrap(40);
        rps.ws_release(10);
        assert_eq!(rps.ledger().free(), 10);
        let grant = rps.provision_idle_to_st();
        assert_eq!(grant, 10);
        assert_eq!(rps.ledger().free(), 0);
    }

    #[test]
    fn static_policy_never_forces() {
        let mut rps = Rps::new(208, PolicyKind::StaticPartition { st: 144, ws: 64 });
        rps.bootstrap(64);
        assert_eq!(rps.ledger().held(crate::cluster::Owner::St), 144);
        // WS asks beyond its partition: nothing from free, nothing forced
        let d = rps.ws_request(10);
        assert_eq!(d.from_free, 0);
        assert_eq!(d.force_from_st, 0);
        assert!(d.denied > 0);
    }
}
