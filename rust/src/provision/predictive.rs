//! The `Predictive` provisioning policy: cooperative flow plus a
//! forecast-driven free-pool reservation that provisions *ahead* of
//! demand instead of reacting to it (the reactive gap called out by
//! arXiv:1710.08731; see [`crate::forecast`]).
//!
//! Mechanism: every tick the driver feeds per-department utilization and
//! demand samples through [`ProvisionPolicy::observe`]; each service
//! department's [`DemandTracker`] forecasts demand one horizon ahead,
//! and the policy keeps a per-department *target* of
//! `ceil(forecast + k·σ)` nodes (σ = demand standard deviation over the
//! window, `k` in tenths from the config's `headroom-tenths` knob).
//!
//! * **Pre-grant** — [`ProvisionPolicy::idle_grants`] withholds the
//!   aggregate gap between targets and current service holdings from
//!   the batch departments, so when the forecasted ramp arrives the
//!   urgent service claim is served straight from the free pool — no
//!   forced returns, no killed batch jobs. A claim fully covered this
//!   way scores a pre-grant *hit*; one that still forces or is denied
//!   scores a *miss* (the matrix's hit-rate column).
//! * **Pre-release** — when the forecast falls, the targets (and with
//!   them the reservation) shrink, and the next idle pass hands the
//!   freed headroom back to the batch departments.
//! * **Cold start** — until a tracker's window fills, no target exists
//!   and every surface behaves exactly like [`super::Cooperative`]
//!   (property-tested in `tests/properties.rs`).
//!
//! Reserving (rather than literally granting ahead) keeps the ledger
//! conservation contract trivially intact and never strands nodes on a
//! service CMS that its own demand loop would release again next tick.

use std::collections::BTreeMap;

use crate::cluster::{DeptId, DeptKind, Ledger};
use crate::forecast::{DemandTracker, ForecastStats};
use crate::sim::SimTime;

use super::policy::{
    cooperative_decision, profile, remove_profile, split_even, upsert_profile, DeptProfile,
    ProvisionDecision, ProvisionPolicy,
};

/// The `[policy]` knobs of the Predictive policy (also CLI flags
/// `--forecast-window`, `--forecast-horizon`, `--headroom-tenths`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PredictiveSpec {
    /// Rolling history length in samples (≥ 2).
    pub window: u32,
    /// Forecast lookahead in seconds.
    pub horizon_secs: u32,
    /// Headroom multiplier k in tenths: reserve `forecast + (k/10)·σ`.
    pub headroom_tenths: u32,
}

impl Default for PredictiveSpec {
    fn default() -> Self {
        Self { window: 16, horizon_secs: 60, headroom_tenths: 20 }
    }
}

/// Forecast + k·σ headroom reservation over the cooperative request flow.
#[derive(Debug)]
pub struct Predictive {
    depts: Vec<DeptProfile>,
    spec: PredictiveSpec,
    /// Per-department demand history (service departments drive targets;
    /// batch trackers feed the sampling/MAE counters only).
    trackers: BTreeMap<DeptId, DemandTracker>,
    /// Active reservation targets, service departments only.
    targets: BTreeMap<DeptId, u64>,
    hits: u64,
    misses: u64,
}

impl Predictive {
    pub fn new(depts: Vec<DeptProfile>, spec: PredictiveSpec) -> Self {
        Self { depts, spec, trackers: BTreeMap::new(), targets: BTreeMap::new(), hits: 0, misses: 0 }
    }

    pub fn spec(&self) -> PredictiveSpec {
        self.spec
    }

    /// Free-pool nodes held back for forecasted service ramps: the sum
    /// over service departments of `max(0, target − held)`. Reservations
    /// never count nodes a department already holds, so a fulfilled
    /// forecast costs the batch side nothing extra.
    pub fn reserved(&self, ledger: &Ledger) -> u64 {
        self.targets.iter().map(|(&d, &t)| t.saturating_sub(ledger.held(d))).sum()
    }
}

impl ProvisionPolicy for Predictive {
    fn name(&self) -> &str {
        "predictive"
    }

    fn on_request(
        &mut self,
        dept: DeptId,
        need: u64,
        ledger: &Ledger,
        _now: SimTime,
    ) -> ProvisionDecision {
        let d = cooperative_decision(&self.depts, dept, need, ledger);
        // score the reservation: only service claims made while a target
        // was live count (cold-start claims are Cooperative's, not ours)
        let service =
            profile(&self.depts, dept).is_some_and(|p| p.kind == DeptKind::Service);
        if service && need > 0 && self.targets.contains_key(&dept) {
            if d.from_free == need {
                self.hits += 1;
            } else {
                self.misses += 1;
            }
        }
        d
    }

    fn idle_grants(
        &mut self,
        ledger: &Ledger,
        eligible: &[DeptId],
        _now: SimTime,
    ) -> Vec<(DeptId, u64)> {
        // cooperative split of whatever the reservation leaves over; with
        // no live targets (cold start) this is exactly Cooperative
        let reserved = self.reserved(ledger);
        split_even(ledger.free().saturating_sub(reserved), eligible)
    }

    fn observe(&mut self, dept: DeptId, util: f64, demand: u64, now: SimTime) {
        let (window, horizon) = (self.spec.window as usize, u64::from(self.spec.horizon_secs));
        let tracker = self
            .trackers
            .entry(dept)
            .or_insert_with(|| DemandTracker::new(window, horizon, 0.3));
        tracker.observe(now, util, demand);
        let service =
            profile(&self.depts, dept).is_some_and(|p| p.kind == DeptKind::Service);
        if !service {
            return;
        }
        match tracker.forecast(now) {
            Some(pred) => {
                let headroom = self.spec.headroom_tenths as f32 / 10.0 * tracker.demand_sigma();
                // f32→u64 saturates on overflow/NaN, so a wild forecast
                // can at worst pause idle grants, never corrupt the ledger
                let target = (pred + headroom).ceil().max(0.0) as u64;
                self.targets.insert(dept, target);
            }
            None => {
                self.targets.remove(&dept);
            }
        }
    }

    fn forecast_stats(&self) -> Option<ForecastStats> {
        let mut stats =
            ForecastStats { hits: self.hits, misses: self.misses, ..ForecastStats::default() };
        for tracker in self.trackers.values() {
            stats.merge(&tracker.stats());
        }
        Some(stats)
    }

    fn on_join(&mut self, profile: DeptProfile, _now: SimTime) {
        upsert_profile(&mut self.depts, profile);
    }

    fn on_leave(&mut self, dept: DeptId, _now: SimTime) {
        // a departed department must neither hold a reservation nor keep
        // feeding the MAE counters
        remove_profile(&mut self.depts, dept);
        self.trackers.remove(&dept);
        self.targets.remove(&dept);
    }

    /// Deliberate no-op: the reservation is the gap between target and
    /// *live* holdings, so a crash (which shrinks holdings through the
    /// ledger) widens the gap automatically; no per-grant state to void.
    fn on_crash(&mut self, _holder: Option<DeptId>, _n: u64, _now: SimTime) {}

    /// Deliberate no-op: repaired nodes re-enter the free pool, where the
    /// reservation-aware `idle_grants` pass already governs them.
    fn on_recover(&mut self, _n: u64, _now: SimTime) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provision::policy::{two_dept_profiles, Cooperative};

    fn ledger(free: u64, st: u64, ws: u64) -> Ledger {
        let mut l = Ledger::new(free + st + ws, 2);
        l.grant(DeptId::ST, st).unwrap();
        l.grant(DeptId::WS, ws).unwrap();
        l
    }

    /// Fill WS's tracker with a rising ramp so a target exists.
    fn warm_up(p: &mut Predictive, demand: &[u64]) {
        for (i, &d) in demand.iter().enumerate() {
            p.observe(DeptId::WS, 0.8, d, i as SimTime * 60);
        }
    }

    #[test]
    fn cold_start_is_exactly_cooperative() {
        let l = ledger(10, 50, 5);
        let mut pred = Predictive::new(two_dept_profiles(144, 64), PredictiveSpec::default());
        let mut coop = Cooperative::new(two_dept_profiles(144, 64));
        assert_eq!(pred.on_request(DeptId::WS, 25, &l, 0), coop.on_request(DeptId::WS, 25, &l, 0));
        assert_eq!(
            pred.idle_grants(&l, &[DeptId::ST], 0),
            coop.idle_grants(&l, &[DeptId::ST], 0)
        );
        assert_eq!(pred.forecast_stats().unwrap().hit_rate(), None);
    }

    #[test]
    fn warm_tracker_reserves_headroom_from_idle_grants() {
        let spec = PredictiveSpec { window: 4, horizon_secs: 120, headroom_tenths: 0 };
        let mut p = Predictive::new(two_dept_profiles(144, 64), spec);
        warm_up(&mut p, &[8, 12, 16, 20]); // +4/step ramp, 2 steps of lookahead
        let target = *p.targets.get(&DeptId::WS).unwrap();
        assert!(target > 20, "target must look past the last sample: {target}");
        // WS holds 5: the gap is reserved, batch gets only the remainder
        let l = ledger(40, 0, 5);
        let reserved = p.reserved(&l);
        assert_eq!(reserved, target - 5);
        let grants = p.idle_grants(&l, &[DeptId::ST], 300);
        let granted: u64 = grants.iter().map(|&(_, n)| n).sum();
        assert_eq!(granted, 40 - reserved, "{grants:?}");
    }

    #[test]
    fn reservation_never_exceeds_free_pool() {
        let spec = PredictiveSpec { window: 4, horizon_secs: 60, headroom_tenths: 50 };
        let mut p = Predictive::new(two_dept_profiles(144, 64), spec);
        warm_up(&mut p, &[10, 40, 90, 160]); // violent ramp, big sigma
        let l = ledger(6, 30, 2);
        let grants = p.idle_grants(&l, &[DeptId::ST], 300);
        let granted: u64 = grants.iter().map(|&(_, n)| n).sum();
        assert!(granted <= l.free(), "over-granted: {grants:?}");
    }

    #[test]
    fn falling_forecast_releases_the_reservation() {
        let spec = PredictiveSpec { window: 4, horizon_secs: 120, headroom_tenths: 0 };
        let mut p = Predictive::new(two_dept_profiles(144, 64), spec);
        warm_up(&mut p, &[20, 16, 12, 8]); // falling ramp, 2 steps of lookahead
        let target = *p.targets.get(&DeptId::WS).unwrap();
        assert!(target < 8, "falling forecast must shrink the target: {target}");
        let l = ledger(40, 0, 8); // WS already holds ≥ target: nothing reserved
        assert_eq!(p.reserved(&l), 0);
        assert_eq!(p.idle_grants(&l, &[DeptId::ST], 300), vec![(DeptId::ST, 40)]);
    }

    #[test]
    fn hits_and_misses_score_only_targeted_service_claims() {
        let spec = PredictiveSpec { window: 4, horizon_secs: 60, headroom_tenths: 10 };
        let mut p = Predictive::new(two_dept_profiles(144, 64), spec);
        // cold start: no scoring
        p.on_request(DeptId::WS, 5, &ledger(10, 20, 0), 0);
        assert_eq!(p.forecast_stats().unwrap().hits + p.forecast_stats().unwrap().misses, 0);
        warm_up(&mut p, &[8, 12, 16, 20]);
        // fully served from free: hit
        p.on_request(DeptId::WS, 5, &ledger(10, 20, 0), 300);
        // forces batch returns: miss
        p.on_request(DeptId::WS, 5, &ledger(2, 20, 0), 360);
        // batch claims never score
        p.on_request(DeptId::ST, 5, &ledger(2, 20, 0), 420);
        let s = p.forecast_stats().unwrap();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.hit_rate(), Some(0.5));
        assert!(s.samples >= 4);
    }

    #[test]
    fn decisions_conserve_nodes_with_live_targets() {
        let spec = PredictiveSpec::default();
        let mut p = Predictive::new(two_dept_profiles(144, 64), spec);
        warm_up(&mut p, &(0..20).map(|i| 5 + i % 7).collect::<Vec<_>>());
        let l = ledger(7, 20, 3);
        for need in [0, 1, 9, 35, 200] {
            let d = p.on_request(DeptId::WS, need, &l, 2000);
            assert_eq!(d.from_free + d.force_total() + d.denied, need, "{d:?}");
            assert!(d.from_free <= l.free());
        }
    }

    #[test]
    fn leave_drops_tracker_target_and_profile() {
        let mut p = Predictive::new(two_dept_profiles(144, 64), PredictiveSpec::default());
        warm_up(&mut p, &[8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60, 64, 68]);
        assert!(p.targets.contains_key(&DeptId::WS));
        p.on_leave(DeptId::WS, 1000);
        assert!(p.targets.is_empty());
        assert!(p.trackers.is_empty());
        let l = ledger(40, 0, 0);
        assert_eq!(p.idle_grants(&l, &[DeptId::ST], 1100), vec![(DeptId::ST, 40)]);
    }

    #[test]
    fn crash_widens_the_gap_through_the_live_ledger() {
        let spec = PredictiveSpec { window: 4, horizon_secs: 60, headroom_tenths: 0 };
        let mut p = Predictive::new(two_dept_profiles(144, 64), spec);
        warm_up(&mut p, &[10, 10, 10, 10]);
        let target = *p.targets.get(&DeptId::WS).unwrap();
        assert_eq!(target, 10);
        // WS holds its whole target: nothing reserved…
        let mut l = ledger(20, 0, 10);
        assert_eq!(p.reserved(&l), 0);
        // …then 4 of its nodes crash: the reservation reopens by itself
        l.crash_held(DeptId::WS, 4).unwrap();
        p.on_crash(Some(DeptId::WS), 4, 500);
        assert_eq!(p.reserved(&l), 4);
    }
}
